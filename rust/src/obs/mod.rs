//! Deterministic event tracing and latency metrics (ISSUE 7).
//!
//! The observability substrate for the repo: per-worker [`EventSink`]s
//! push structured [`Event`]s into thread-local buffers that are swapped
//! out over an `mpsc` channel (no locks, no allocation on the common
//! path), and a [`TraceCollector`] drains them into a [`Timeline`] whose
//! canonical order depends only on the run's *logical clocks* — tenant,
//! epoch, frame, sequence — never wall time. A drained timeline is
//! therefore byte-identical across thread counts, pacing
//! (`--realtime-scale`), and injected stragglers, exactly like reports.
//!
//! Capture is gated by a single boolean per sink: with tracing disabled
//! (`--trace-out` absent) the hot path pays one branch, which the gated
//! `obs/on_frame_overhead` bench holds to budget. Always-on counters and
//! the streaming histograms in [`hist`] are separate from capture and
//! never turn off.

pub mod hist;

pub use hist::{EpochLatencies, Histogram, HIST_BUCKETS, HIST_GROWTH, HIST_MIN_MS};

use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Buffered events per sink before the buffer is swapped out to the
/// collector (`mem::take` + channel send — the "ring" rotation).
const FLUSH_EVENTS: usize = 1024;

/// What happened. Payloads carry the decision inputs/outputs that the
/// `inspect` views render; all values are logical or deterministic model
/// quantities (virtual-time latencies, knob vectors, core grants) —
/// never wall-clock readings.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A frame entered the pipeline with this knob vector (live only).
    FrameStart { knobs: Vec<f64> },
    /// A frame completed: end-to-end latency, per-stage latencies
    /// (empty where stages are not tracked), and fidelity/reward.
    Frame {
        ms: f64,
        stage_ms: Vec<f64>,
        fidelity: f64,
    },
    /// A knob schedule was extended for one tenant.
    Knobs {
        from_frame: usize,
        horizon: usize,
        knobs: Vec<f64>,
    },
    /// A tenant was parked by admission control.
    Park,
    /// A parked tenant was re-admitted, fast-forwarded to this epoch.
    Resume { at_epoch: usize },
    /// The completion frontier passed this epoch, releasing a decision.
    Frontier { passed: usize },
    /// An admission decision: who runs this epoch, with the per-tenant
    /// core demand summaries it was based on.
    Admission {
        admitted: Vec<bool>,
        reservations: Vec<usize>,
    },
    /// A core allocation across tenants, with churn vs the previous one.
    Alloc {
        cores: Vec<usize>,
        parked: Vec<bool>,
        churn_cores: usize,
    },
    /// One shard's slice of an allocation (sharded fleets only): the
    /// owning shard id, its contiguous tenant range `[lo, hi)`, and the
    /// granted cores for exactly that range. Emitted with `seq = shard`
    /// so per-epoch shard events keep unique logical-clock keys.
    ShardAlloc {
        shard: usize,
        lo: usize,
        hi: usize,
        cores: Vec<usize>,
    },
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::FrameStart { .. } => "frame_start",
            EventKind::Frame { .. } => "frame",
            EventKind::Knobs { .. } => "knobs",
            EventKind::Park => "park",
            EventKind::Resume { .. } => "resume",
            EventKind::Frontier { .. } => "frontier",
            EventKind::Admission { .. } => "admission",
            EventKind::Alloc { .. } => "alloc",
            EventKind::ShardAlloc { .. } => "shard_alloc",
        }
    }

    /// Tie-break rank within one (epoch, tenant, frame, seq) cell; also
    /// fixes the semantic order of same-epoch control events (frontier
    /// advance, then admission, then allocation).
    fn rank(&self) -> usize {
        match self {
            EventKind::FrameStart { .. } => 0,
            EventKind::Frame { .. } => 1,
            EventKind::Knobs { .. } => 2,
            EventKind::Park => 3,
            EventKind::Resume { .. } => 4,
            EventKind::Frontier { .. } => 5,
            EventKind::Admission { .. } => 6,
            EventKind::Alloc { .. } => 7,
            EventKind::ShardAlloc { .. } => 8,
        }
    }
}

/// One trace event, stamped with logical clocks only.
///
/// `tenant == None` marks a run-global (scheduler) event; `frame ==
/// None` marks a control event not tied to one frame. Within an epoch
/// the canonical order is: per-tenant frame events (by frame, then
/// seq), per-tenant control events, then global control events.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub tenant: Option<usize>,
    pub epoch: usize,
    pub frame: Option<usize>,
    pub seq: usize,
    pub kind: EventKind,
}

impl Event {
    fn key(&self) -> (usize, usize, usize, usize, usize) {
        (
            self.epoch,
            self.tenant.unwrap_or(usize::MAX),
            self.frame.unwrap_or(usize::MAX),
            self.seq,
            self.kind.rank(),
        )
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<usize>| match v {
            Some(x) => Json::from(x),
            None => Json::Null,
        };
        let j = Json::obj()
            .put("tenant", opt(self.tenant))
            .put("epoch", self.epoch)
            .put("frame", opt(self.frame))
            .put("seq", self.seq)
            .put("kind", self.kind.name());
        let usizes = |xs: &[usize]| Json::Arr(xs.iter().map(|&x| Json::from(x)).collect());
        let bools = |xs: &[bool]| Json::Arr(xs.iter().map(|&x| Json::from(x)).collect());
        match &self.kind {
            EventKind::FrameStart { knobs } => j.put("knobs", Json::from_f64_slice(knobs)),
            EventKind::Frame {
                ms,
                stage_ms,
                fidelity,
            } => j
                .put("ms", *ms)
                .put("stage_ms", Json::from_f64_slice(stage_ms))
                .put("fidelity", *fidelity),
            EventKind::Knobs {
                from_frame,
                horizon,
                knobs,
            } => j
                .put("from_frame", *from_frame)
                .put("horizon", *horizon)
                .put("knobs", Json::from_f64_slice(knobs)),
            EventKind::Park => j,
            EventKind::Resume { at_epoch } => j.put("at_epoch", *at_epoch),
            EventKind::Frontier { passed } => j.put("passed", *passed),
            EventKind::Admission {
                admitted,
                reservations,
            } => j
                .put("admitted", bools(admitted))
                .put("reservations", usizes(reservations)),
            EventKind::Alloc {
                cores,
                parked,
                churn_cores,
            } => j
                .put("cores", usizes(cores))
                .put("parked", bools(parked))
                .put("churn_cores", *churn_cores),
            EventKind::ShardAlloc { shard, lo, hi, cores } => j
                .put("shard", *shard)
                .put("lo", *lo)
                .put("hi", *hi)
                .put("cores", usizes(cores)),
        }
    }

    pub fn from_json(j: &Json) -> Result<Event> {
        let opt = |key: &str| -> Result<Option<usize>> {
            match j.get(key) {
                None => bail!("event missing {key:?}"),
                Some(Json::Null) => Ok(None),
                Some(v) => Ok(Some(v.as_usize()?)),
            }
        };
        let bools = |key: &str| -> Result<Vec<bool>> {
            j.req(key)?.as_arr()?.iter().map(|v| v.as_bool()).collect()
        };
        let kind = match j.req("kind")?.as_str()? {
            "frame_start" => EventKind::FrameStart {
                knobs: j.req("knobs")?.as_f64_vec()?,
            },
            "frame" => EventKind::Frame {
                ms: j.req("ms")?.as_f64()?,
                stage_ms: j.req("stage_ms")?.as_f64_vec()?,
                fidelity: j.req("fidelity")?.as_f64()?,
            },
            "knobs" => EventKind::Knobs {
                from_frame: j.req("from_frame")?.as_usize()?,
                horizon: j.req("horizon")?.as_usize()?,
                knobs: j.req("knobs")?.as_f64_vec()?,
            },
            "park" => EventKind::Park,
            "resume" => EventKind::Resume {
                at_epoch: j.req("at_epoch")?.as_usize()?,
            },
            "frontier" => EventKind::Frontier {
                passed: j.req("passed")?.as_usize()?,
            },
            "admission" => EventKind::Admission {
                admitted: bools("admitted")?,
                reservations: j.req("reservations")?.as_usize_vec()?,
            },
            "alloc" => EventKind::Alloc {
                cores: j.req("cores")?.as_usize_vec()?,
                parked: bools("parked")?,
                churn_cores: j.req("churn_cores")?.as_usize()?,
            },
            "shard_alloc" => EventKind::ShardAlloc {
                shard: j.req("shard")?.as_usize()?,
                lo: j.req("lo")?.as_usize()?,
                hi: j.req("hi")?.as_usize()?,
                cores: j.req("cores")?.as_usize_vec()?,
            },
            other => bail!("unknown event kind {other:?}"),
        };
        Ok(Event {
            tenant: opt("tenant")?,
            epoch: j.req("epoch")?.as_usize()?,
            frame: opt("frame")?,
            seq: j.req("seq")?.as_usize()?,
            kind,
        })
    }
}

/// Sort events into canonical (logical-clock) order. Every recorded
/// event has a unique key by construction, so the order is total and
/// independent of arrival order.
pub fn sort_events(events: &mut [Event]) {
    events.sort_unstable_by_key(|e| e.key());
}

/// Per-worker event buffer. `record_with` takes a closure so the event
/// payload is never even constructed when capture is disabled — the
/// disabled path is a single branch (gated bench `obs/on_frame_overhead`).
pub struct EventSink {
    enabled: bool,
    buf: Vec<Event>,
    tx: Option<Sender<Vec<Event>>>,
}

impl EventSink {
    /// A sink that drops everything; useful as a default/bench stand-in.
    pub fn disabled() -> EventSink {
        EventSink {
            enabled: false,
            buf: Vec::new(),
            tx: None,
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    pub fn record_with<F: FnOnce() -> Event>(&mut self, make: F) {
        if !self.enabled {
            return;
        }
        self.buf.push(make());
        if self.buf.len() >= FLUSH_EVENTS {
            self.flush();
        }
    }

    /// Swap the buffer out to the collector.
    pub fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        match &self.tx {
            Some(tx) => {
                let _ = tx.send(std::mem::take(&mut self.buf));
            }
            None => self.buf.clear(),
        }
    }

    /// Flush and detach from the collector so a later
    /// [`TraceCollector::drain`] does not wait on this sink.
    pub fn close(&mut self) {
        self.flush();
        self.tx = None;
    }
}

impl Drop for EventSink {
    fn drop(&mut self) {
        self.flush();
    }
}

impl std::fmt::Debug for EventSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventSink")
            .field("enabled", &self.enabled)
            .field("buffered", &self.buf.len())
            .finish()
    }
}

/// Hands out sinks to workers and drains their buffers into a
/// canonically ordered event list.
pub struct TraceCollector {
    enabled: bool,
    tx: Sender<Vec<Event>>,
    rx: Receiver<Vec<Event>>,
}

impl TraceCollector {
    pub fn new(enabled: bool) -> TraceCollector {
        let (tx, rx) = channel();
        TraceCollector { enabled, tx, rx }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn sink(&self) -> EventSink {
        EventSink {
            enabled: self.enabled,
            buf: Vec::new(),
            tx: Some(self.tx.clone()),
        }
    }

    /// Collect every flushed buffer and sort. All sinks must have been
    /// dropped or [`EventSink::close`]d by now (drain would otherwise
    /// wait for them).
    pub fn drain(self) -> Vec<Event> {
        let TraceCollector { tx, rx, .. } = self;
        drop(tx);
        let mut events = Vec::new();
        while let Ok(mut batch) = rx.recv() {
            events.append(&mut batch);
        }
        sort_events(&mut events);
        events
    }
}

/// A saved trace: run identity plus the canonically ordered events.
/// Serialized as a versioned JSON artifact (`--trace-out PATH`) and read
/// back by the `inspect` subcommand and `scripts/validate_timeline.py`.
#[derive(Debug, Clone, PartialEq)]
pub struct Timeline {
    /// `"fleet"` or `"live"`.
    pub source: String,
    pub seed: u64,
    pub apps: usize,
    pub frames: usize,
    pub epoch_frames: usize,
    pub events: Vec<Event>,
}

pub const TIMELINE_VERSION: u64 = 1;

impl Timeline {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .put("version", TIMELINE_VERSION)
            .put("kind", "iptune-timeline")
            .put("source", self.source.as_str())
            .put("seed", self.seed)
            .put("apps", self.apps)
            .put("frames", self.frames)
            .put("epoch_frames", self.epoch_frames)
            .put(
                "events",
                Json::Arr(self.events.iter().map(|e| e.to_json()).collect()),
            )
    }

    pub fn from_json(j: &Json) -> Result<Timeline> {
        let version = j.req("version")?.as_u64()?;
        if version != TIMELINE_VERSION {
            bail!("unsupported timeline version {version}");
        }
        let kind = j.req("kind")?.as_str()?;
        if kind != "iptune-timeline" {
            bail!("not a timeline artifact (kind {kind:?})");
        }
        let events = j
            .req("events")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, e)| Event::from_json(e).with_context(|| format!("event {i}")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Timeline {
            source: j.req("source")?.as_str()?.to_string(),
            seed: j.req("seed")?.as_u64()?,
            apps: j.req("apps")?.as_usize()?,
            frames: j.req("frames")?.as_usize()?,
            epoch_frames: j.req("epoch_frames")?.as_usize()?,
            events,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut text = self.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Timeline> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Timeline::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing timeline {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_event(tenant: usize, epoch: usize, frame: usize, ms: f64) -> Event {
        Event {
            tenant: Some(tenant),
            epoch,
            frame: Some(frame),
            seq: 1,
            kind: EventKind::Frame {
                ms,
                stage_ms: vec![ms * 0.5, ms * 0.5],
                fidelity: 0.9,
            },
        }
    }

    #[test]
    fn disabled_sink_records_nothing_and_is_cheap_to_drop() {
        let collector = TraceCollector::new(false);
        let mut sink = collector.sink();
        let mut built = 0;
        sink.record_with(|| {
            built += 1;
            frame_event(0, 0, 0, 1.0)
        });
        drop(sink);
        assert_eq!(built, 0, "payload closure must not run when disabled");
        assert!(collector.drain().is_empty());
    }

    #[test]
    fn drain_orders_events_canonically_regardless_of_arrival() {
        let collector = TraceCollector::new(true);
        let mut expect = Vec::new();
        std::thread::scope(|s| {
            for w in 0..3usize {
                let mut sink = collector.sink();
                s.spawn(move || {
                    // Deliberately record epochs out of order.
                    for epoch in [1usize, 0] {
                        for f in 0..4usize {
                            sink.record_with(|| frame_event(w, epoch, epoch * 4 + f, 2.0));
                        }
                    }
                });
            }
        });
        let mut sched = collector.sink();
        sched.record_with(|| Event {
            tenant: None,
            epoch: 0,
            frame: None,
            seq: 0,
            kind: EventKind::Alloc {
                cores: vec![4, 4, 4],
                parked: vec![false; 3],
                churn_cores: 0,
            },
        });
        sched.close();
        for epoch in 0..2usize {
            for w in 0..3usize {
                for f in 0..4usize {
                    expect.push(frame_event(w, epoch, epoch * 4 + f, 2.0));
                }
            }
            if epoch == 0 {
                expect.push(Event {
                    tenant: None,
                    epoch: 0,
                    frame: None,
                    seq: 0,
                    kind: EventKind::Alloc {
                        cores: vec![4, 4, 4],
                        parked: vec![false; 3],
                        churn_cores: 0,
                    },
                });
            }
        }
        let events = collector.drain();
        assert_eq!(events, expect);
    }

    #[test]
    fn timeline_json_round_trips() {
        let mut events = vec![
            Event {
                tenant: Some(1),
                epoch: 0,
                frame: None,
                seq: 0,
                kind: EventKind::Knobs {
                    from_frame: 0,
                    horizon: 30,
                    knobs: vec![2.0, 1024.0],
                },
            },
            Event {
                tenant: None,
                epoch: 0,
                frame: None,
                seq: 0,
                kind: EventKind::Admission {
                    admitted: vec![true, false],
                    reservations: vec![3, 5],
                },
            },
            Event {
                tenant: Some(0),
                epoch: 1,
                frame: None,
                seq: 0,
                kind: EventKind::Park,
            },
            Event {
                tenant: Some(0),
                epoch: 2,
                frame: None,
                seq: 0,
                kind: EventKind::Resume { at_epoch: 2 },
            },
            Event {
                tenant: None,
                epoch: 2,
                frame: None,
                seq: 0,
                kind: EventKind::Frontier { passed: 1 },
            },
            frame_event(0, 0, 3, 12.5),
            Event {
                tenant: Some(0),
                epoch: 0,
                frame: Some(3),
                seq: 0,
                kind: EventKind::FrameStart {
                    knobs: vec![2.0, 1024.0],
                },
            },
        ];
        sort_events(&mut events);
        let tl = Timeline {
            source: "live".to_string(),
            seed: 42,
            apps: 2,
            frames: 60,
            epoch_frames: 30,
            events,
        };
        let text = tl.to_json().to_string();
        let back = Timeline::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, tl);
        assert_eq!(back.to_json().to_string(), text);
    }
}
