//! Streaming log-spaced latency histograms (ISSUE 7).
//!
//! Fixed geometric buckets so that recording is allocation-free and two
//! histograms merge by element-wise addition — the properties that let
//! per-(tenant, epoch) latency distributions stream on the hot path and
//! still aggregate deterministically at report time. Bucket boundaries
//! are produced by *repeated* `f64` multiplication from [`HIST_MIN_MS`]
//! (never `ln`/`powf`), so the exact same bit pattern falls out of the
//! Python mirror (`python/tests/test_obs_mirror.py`) and quantiles are
//! byte-identical across platforms, thread counts, and pacing.
//!
//! Quantiles are resolved to the *upper edge* of the bucket holding the
//! rank-`ceil(q*n)` sample, clamped to the observed maximum — which makes
//! the single-sample and saturating-top-bucket cases exact instead of
//! merely approximate.

use crate::util::Json;

/// Lower edge of bucket 1 (ms). Bucket 0 is `[0, HIST_MIN_MS)`.
pub const HIST_MIN_MS: f64 = 0.05;
/// Geometric growth factor between consecutive bucket edges (~12%
/// relative resolution).
pub const HIST_GROWTH: f64 = 1.12;
/// Number of finite bucket edges; the histogram has `HIST_BUCKETS + 1`
/// counters, the last one saturating (`[top_edge, inf)`). The span is
/// roughly 0.05 ms .. 89 s.
pub const HIST_BUCKETS: usize = 128;

/// A fixed-bucket latency histogram with exact count/sum/min/max.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum_ms: f64,
    min_ms: f64,
    max_ms: f64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; HIST_BUCKETS + 1],
            count: 0,
            sum_ms: 0.0,
            min_ms: f64::INFINITY,
            max_ms: f64::NEG_INFINITY,
        }
    }

    /// Record one latency sample. Non-finite or negative values are
    /// clamped to 0 (bucket 0) so counters stay sane on degenerate input.
    #[inline]
    pub fn record(&mut self, ms: f64) {
        let v = if ms.is_finite() && ms > 0.0 { ms } else { 0.0 };
        // Count edges <= v by walking the geometric edge sequence with
        // the same repeated multiplication the mirror uses; the walk
        // early-exits at the first edge above the sample.
        let mut idx = 0usize;
        let mut edge = HIST_MIN_MS;
        while idx < HIST_BUCKETS && edge <= v {
            edge *= HIST_GROWTH;
            idx += 1;
        }
        self.counts[idx] += 1;
        self.count += 1;
        self.sum_ms += v;
        self.min_ms = self.min_ms.min(v);
        self.max_ms = self.max_ms.max(v);
    }

    /// Element-wise merge; equivalent to having recorded the union of
    /// both sample streams (in any order).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.count += other.count;
        self.sum_ms += other.sum_ms;
        self.min_ms = self.min_ms.min(other.min_ms);
        self.max_ms = self.max_ms.max(other.max_ms);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum_ms(&self) -> f64 {
        self.sum_ms
    }

    pub fn min_ms(&self) -> Option<f64> {
        (self.count > 0).then_some(self.min_ms)
    }

    pub fn max_ms(&self) -> Option<f64> {
        (self.count > 0).then_some(self.max_ms)
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// The q-quantile (0 < q <= 1): upper edge of the bucket holding the
    /// rank-`max(1, ceil(q*n))` sample, clamped to the observed max.
    /// `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        // detlint: allow(lossy-cast) — rank: ceil of q*count is exact below 2^53 and clamped to >= 1
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        let mut edge = HIST_MIN_MS;
        for (i, c) in self.counts.iter().enumerate() {
            cum += *c;
            if cum >= rank {
                let upper = if i == HIST_BUCKETS { f64::INFINITY } else { edge };
                return Some(upper.min(self.max_ms));
            }
            edge *= HIST_GROWTH;
        }
        Some(self.max_ms)
    }

    /// Append the standard summary fields (`count`/`p50`/`p95`/`p99`/
    /// `max_ms`) to a JSON object under construction.
    pub fn summary_fields(&self, j: Json) -> Json {
        let q = |p: f64| match self.quantile(p) {
            Some(v) => Json::from(v),
            None => Json::Null,
        };
        j.put("count", self.count)
            .put("p50", q(0.50))
            .put("p95", q(0.95))
            .put("p99", q(0.99))
            .put(
                "max_ms",
                match self.max_ms() {
                    Some(v) => Json::from(v),
                    None => Json::Null,
                },
            )
    }

    pub fn summary_json(&self) -> Json {
        self.summary_fields(Json::obj())
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Per-epoch latency histograms for one tenant, plus a deterministic
/// whole-run merge. Epoch slots are pre-sized so epochs a tenant never
/// ran (parked) still appear in the report with `count == 0`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EpochLatencies {
    epochs: Vec<Histogram>,
}

impl EpochLatencies {
    pub fn with_epochs(n: usize) -> EpochLatencies {
        EpochLatencies {
            epochs: vec![Histogram::new(); n],
        }
    }

    #[inline]
    pub fn record(&mut self, epoch: usize, ms: f64) {
        if epoch >= self.epochs.len() {
            self.epochs.resize(epoch + 1, Histogram::new());
        }
        self.epochs[epoch].record(ms);
    }

    pub fn epochs(&self) -> &[Histogram] {
        &self.epochs
    }

    /// Whole-run histogram: per-epoch histograms merged in epoch order.
    pub fn total(&self) -> Histogram {
        let mut t = Histogram::new();
        for h in &self.epochs {
            t.merge(h);
        }
        t
    }

    /// `[{"epoch", "count", "p50", "p95", "p99"}, ...]`, one row per
    /// epoch (empty epochs included with null percentiles).
    pub fn to_json(&self) -> Json {
        let rows = self
            .epochs
            .iter()
            .enumerate()
            .map(|(e, h)| {
                let q = |p: f64| match h.quantile(p) {
                    Some(v) => Json::from(v),
                    None => Json::Null,
                };
                Json::obj()
                    .put("epoch", e)
                    .put("count", h.count())
                    .put("p50", q(0.50))
                    .put("p95", q(0.95))
                    .put("p99", q(0.99))
            })
            .collect::<Vec<_>>();
        Json::Arr(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boundaries() -> Vec<f64> {
        let mut b = Vec::with_capacity(HIST_BUCKETS);
        let mut edge = HIST_MIN_MS;
        for _ in 0..HIST_BUCKETS {
            b.push(edge);
            edge *= HIST_GROWTH;
        }
        b
    }

    #[test]
    fn empty_histogram_has_no_quantiles() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.quantile(0.99), None);
        assert_eq!(h.max_ms(), None);
        let j = h.summary_json().to_string();
        assert!(j.contains("\"p50\":null"), "{j}");
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let mut h = Histogram::new();
        h.record(37.25);
        for q in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), Some(37.25), "q={q}");
        }
    }

    #[test]
    fn saturating_top_bucket_clamps_to_max() {
        let mut h = Histogram::new();
        h.record(1.0e9); // far above the ~89 s top edge
        h.record(2.0e9);
        assert_eq!(h.bucket_counts()[HIST_BUCKETS], 2);
        assert_eq!(h.quantile(0.99), Some(2.0e9));
        assert_eq!(h.quantile(0.5), Some(2.0e9)); // both in one bucket
    }

    #[test]
    fn boundary_sample_lands_in_upper_bucket() {
        // An edge value v == boundaries[k] must count toward bucket k+1
        // (edges are half-open on the right): mirror of bisect_right.
        let b = boundaries();
        let mut h = Histogram::new();
        h.record(b[7]);
        assert_eq!(h.bucket_counts()[8], 1);
        // Just below the edge stays in bucket 7.
        let mut g = Histogram::new();
        g.record(b[7] * (1.0 - 1e-12));
        assert_eq!(g.bucket_counts()[7], 1);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let samples = [0.01, 0.05, 0.4, 3.0, 3.1, 40.0, 41.5, 900.0, 5e5];
        let mut all = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, &s) in samples.iter().enumerate() {
            all.record(s);
            if i % 2 == 0 {
                a.record(s)
            } else {
                b.record(s)
            }
        }
        a.merge(&b);
        assert_eq!(a, all);
        assert_eq!(a.quantile(0.95), all.quantile(0.95));
    }

    #[test]
    fn quantiles_are_monotone_and_bounded() {
        let mut h = Histogram::new();
        let mut v = 0.07;
        for _ in 0..500 {
            h.record(v);
            v = (v * 1.17) % 2000.0 + 0.05;
        }
        let mut prev = 0.0;
        for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
            let x = h.quantile(q).unwrap();
            assert!(x >= prev, "q={q}: {x} < {prev}");
            assert!(x <= h.max_ms().unwrap());
            prev = x;
        }
        assert_eq!(h.quantile(1.0), h.max_ms());
    }

    #[test]
    fn epoch_latencies_total_merges_in_order_and_keeps_empty_epochs() {
        let mut el = EpochLatencies::with_epochs(3);
        el.record(0, 10.0);
        el.record(2, 20.0);
        el.record(2, 30.0);
        assert_eq!(el.epochs()[1].count(), 0);
        let t = el.total();
        assert_eq!(t.count(), 3);
        assert_eq!(t.max_ms(), Some(30.0));
        let j = el.to_json().to_string();
        assert!(j.contains("\"epoch\":1,\"count\":0,\"p50\":null"), "{j}");
    }
}
