//! Data-flow graph substrate (paper Sec. 2 / Sec. 3).
//!
//! Applications are directed acyclic graphs whose vertices are
//! coarse-grained sequential *stages* and whose edges are *connectors*
//! (data dependencies). Stage weights are per-execution latencies; the
//! application latency is the length of the weighted critical path
//! through the graph (paper Sec. 3: `c = Σ_{i∈C} w_i`).

pub mod critical_path;

pub use critical_path::{critical_path, critical_path_nodes};

use anyhow::{bail, Result};

use crate::apps::spec::AppSpec;

/// Stage index within a [`Graph`].
pub type StageId = usize;

/// A stage vertex.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    /// Upstream stages (connector sources).
    pub deps: Vec<StageId>,
}

/// A data-flow DAG in topological order.
#[derive(Debug, Clone)]
pub struct Graph {
    nodes: Vec<Node>,
}

impl Graph {
    /// Build from (name, deps-by-name) pairs listed in topological order.
    pub fn new(stages: &[(String, Vec<String>)]) -> Result<Self> {
        let mut nodes: Vec<Node> = Vec::with_capacity(stages.len());
        for (name, deps) in stages {
            let mut dep_ids = Vec::with_capacity(deps.len());
            for d in deps {
                match nodes.iter().position(|n| &n.name == d) {
                    Some(i) => dep_ids.push(i),
                    None => bail!("stage {name}: dep {d} not defined earlier (not topological?)"),
                }
            }
            if nodes.iter().any(|n| &n.name == name) {
                bail!("duplicate stage {name}");
            }
            nodes.push(Node { name: name.clone(), deps: dep_ids });
        }
        Ok(Graph { nodes })
    }

    /// Build the application graph declared in a spec.
    pub fn from_spec(spec: &AppSpec) -> Self {
        let stages: Vec<(String, Vec<String>)> = spec
            .stages
            .iter()
            .map(|s| (s.name.clone(), s.deps.clone()))
            .collect();
        // detlint: allow(unwrap) — AppSpec::validate() checks the stage DAG before any Graph is built
        Graph::new(&stages).expect("spec graphs are validated at load")
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: StageId) -> &Node {
        &self.nodes[id]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn index_of(&self, name: &str) -> Option<StageId> {
        self.nodes.iter().position(|n| n.name == name)
    }

    /// Stages with no outgoing connectors.
    pub fn sinks(&self) -> Vec<StageId> {
        let mut has_out = vec![false; self.nodes.len()];
        for n in &self.nodes {
            for &d in &n.deps {
                has_out[d] = true;
            }
        }
        (0..self.nodes.len()).filter(|&i| !has_out[i]).collect()
    }

    /// Stages with no incoming connectors.
    pub fn sources(&self) -> Vec<StageId> {
        (0..self.nodes.len())
            .filter(|&i| self.nodes[i].deps.is_empty())
            .collect()
    }

    /// Downstream adjacency (successors of every stage).
    pub fn successors(&self) -> Vec<Vec<StageId>> {
        let mut succ = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &d in &n.deps {
                succ[d].push(i);
            }
        }
        succ
    }

    /// Graphviz DOT rendering (used by `repro spec --graph`, reproducing
    /// the paper's Figures 1 and 4).
    pub fn to_dot(&self, title: &str) -> String {
        let mut out = format!("digraph \"{title}\" {{\n  rankdir=LR;\n");
        for n in &self.nodes {
            out.push_str(&format!("  \"{}\" [shape=box];\n", n.name));
        }
        for n in &self.nodes {
            for &d in &n.deps {
                out.push_str(&format!("  \"{}\" -> \"{}\";\n", self.nodes[d].name, n.name));
            }
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Graph {
        Graph::new(&[
            ("a".into(), vec![]),
            ("b".into(), vec!["a".into()]),
            ("c".into(), vec!["b".into()]),
        ])
        .unwrap()
    }

    fn diamond() -> Graph {
        Graph::new(&[
            ("src".into(), vec![]),
            ("l".into(), vec!["src".into()]),
            ("r".into(), vec!["src".into()]),
            ("snk".into(), vec!["l".into(), "r".into()]),
        ])
        .unwrap()
    }

    #[test]
    fn chain_sources_sinks() {
        let g = chain();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![2]);
    }

    #[test]
    fn diamond_structure() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
        assert_eq!(g.successors()[0], vec![1, 2]);
    }

    #[test]
    fn forward_reference_rejected() {
        let r = Graph::new(&[("a".into(), vec!["b".into()]), ("b".into(), vec![])]);
        assert!(r.is_err());
    }

    #[test]
    fn duplicate_rejected() {
        let r = Graph::new(&[("a".into(), vec![]), ("a".into(), vec![])]);
        assert!(r.is_err());
    }

    #[test]
    fn dot_contains_edges() {
        let dot = diamond().to_dot("d");
        assert!(dot.contains("\"src\" -> \"l\""));
        assert!(dot.contains("\"r\" -> \"snk\""));
    }

    #[test]
    fn spec_graphs_build() {
        let dir = crate::apps::spec::find_spec_dir(None).unwrap();
        for name in ["pose", "motion_sift"] {
            let spec = AppSpec::load_named(name, &dir).unwrap();
            let g = Graph::from_spec(&spec);
            assert_eq!(g.len(), spec.stages.len());
            assert_eq!(g.sinks().len(), 1, "{name} should have one sink");
        }
    }
}
