//! Weighted critical-path computation (paper Sec. 3: the application
//! latency is the length of the longest weighted path through the DAG).

use super::{Graph, StageId};

/// Length of the critical path where `weights[i]` is stage `i`'s latency.
///
/// O(V + E): one pass in topological order (graphs are stored
/// topologically). Panics if `weights.len() != g.len()`.
///
/// Signed weights: every join anchors at zero (`fold(0.0, max)` over
/// parent distances) — the identity that makes source nodes start from
/// zero *also clamps negative partial path sums*, and so does the
/// zero-initialized running `best`. Callers feeding signed predictions
/// (the learner's DAG `combine`) rely on that clamp for small transient
/// undershoots and validate magnitude themselves.
pub fn critical_path(g: &Graph, weights: &[f64]) -> f64 {
    assert_eq!(weights.len(), g.len());
    let mut dist = vec![0.0f64; g.len()];
    let mut best = 0.0f64;
    for (i, node) in g.nodes().iter().enumerate() {
        let longest_in = node
            .deps
            .iter()
            .map(|&d| dist[d])
            .fold(0.0f64, f64::max);
        dist[i] = longest_in + weights[i];
        best = best.max(dist[i]);
    }
    best
}

/// The critical path itself, as stage ids from source to sink.
pub fn critical_path_nodes(g: &Graph, weights: &[f64]) -> Vec<StageId> {
    assert_eq!(weights.len(), g.len());
    let mut dist = vec![0.0f64; g.len()];
    let mut prev: Vec<Option<StageId>> = vec![None; g.len()];
    for (i, node) in g.nodes().iter().enumerate() {
        let mut longest_in = 0.0f64;
        for &d in &node.deps {
            if dist[d] > longest_in {
                longest_in = dist[d];
                prev[i] = Some(d);
            }
        }
        dist[i] = longest_in + weights[i];
    }
    let mut end = 0;
    for i in 0..g.len() {
        if dist[i] > dist[end] {
            end = i;
        }
    }
    let mut path = vec![end];
    // detlint: allow(unwrap) — path is seeded with the sink node before the backwalk
    while let Some(p) = prev[*path.last().unwrap()] {
        path.push(p);
    }
    path.reverse();
    path
}

/// Critical path with *edge* weights (paper Sec. 3: "inter-stage
/// communication latency ... can be incorporated by adding edge weights
/// that represent communication costs"). `edge_ms(src, dst)` is the
/// connector cost; the future-work extension the paper names.
pub fn critical_path_with_edges(
    g: &Graph,
    weights: &[f64],
    edge_ms: impl Fn(StageId, StageId) -> f64,
) -> f64 {
    assert_eq!(weights.len(), g.len());
    let mut dist = vec![0.0f64; g.len()];
    let mut best = 0.0f64;
    for (i, node) in g.nodes().iter().enumerate() {
        let longest_in = node
            .deps
            .iter()
            .map(|&d| dist[d] + edge_ms(d, i))
            .fold(0.0f64, f64::max);
        dist[i] = longest_in + weights[i];
        best = best.max(dist[i]);
    }
    best
}

/// Brute-force critical path by enumerating every source-to-any path.
/// Exponential; used only to validate `critical_path` in tests/proptests.
pub fn critical_path_brute(g: &Graph, weights: &[f64]) -> f64 {
    fn dfs(g: &Graph, succ: &[Vec<StageId>], w: &[f64], i: StageId, acc: f64, best: &mut f64) {
        let acc = acc + w[i];
        *best = best.max(acc);
        for &s in &succ[i] {
            dfs(g, succ, w, s, acc, best);
        }
    }
    let succ = g.successors();
    let mut best = 0.0;
    for s in g.sources() {
        dfs(g, &succ, weights, s, 0.0, &mut best);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Graph;

    fn diamond() -> Graph {
        Graph::new(&[
            ("src".into(), vec![]),
            ("l".into(), vec!["src".into()]),
            ("r".into(), vec!["src".into()]),
            ("snk".into(), vec!["l".into(), "r".into()]),
        ])
        .unwrap()
    }

    #[test]
    fn chain_is_sum() {
        let g = Graph::new(&[
            ("a".into(), vec![]),
            ("b".into(), vec!["a".into()]),
            ("c".into(), vec!["b".into()]),
        ])
        .unwrap();
        assert_eq!(critical_path(&g, &[1.0, 2.0, 3.0]), 6.0);
    }

    #[test]
    fn diamond_takes_max_branch() {
        let g = diamond();
        // paper Sec. 2.3: sum of seq stages + max of the branches
        assert_eq!(critical_path(&g, &[1.0, 5.0, 2.0, 1.0]), 7.0);
        assert_eq!(critical_path(&g, &[1.0, 2.0, 9.0, 1.0]), 11.0);
    }

    #[test]
    fn matches_brute_force_on_motion_sift() {
        let dir = crate::apps::spec::find_spec_dir(None).unwrap();
        let spec = crate::apps::spec::AppSpec::load_named("motion_sift", &dir).unwrap();
        let g = Graph::from_spec(&spec);
        let w: Vec<f64> = (0..g.len()).map(|i| (i as f64 * 7.3) % 11.0 + 0.5).collect();
        assert!((critical_path(&g, &w) - critical_path_brute(&g, &w)).abs() < 1e-9);
    }

    #[test]
    fn path_nodes_consistent_with_length() {
        let g = diamond();
        let w = [1.0, 5.0, 2.0, 1.0];
        let path = critical_path_nodes(&g, &w);
        let len: f64 = path.iter().map(|&i| w[i]).sum();
        assert_eq!(len, critical_path(&g, &w));
        assert_eq!(path, vec![0, 1, 3]);
    }

    #[test]
    fn disconnected_components() {
        let g = Graph::new(&[
            ("a".into(), vec![]),
            ("b".into(), vec![]),
        ])
        .unwrap();
        assert_eq!(critical_path(&g, &[3.0, 4.0]), 4.0);
    }

    #[test]
    fn zero_weights() {
        let g = diamond();
        assert_eq!(critical_path(&g, &[0.0; 4]), 0.0);
    }

    #[test]
    fn edge_weights_extend_the_path() {
        let g = diamond();
        let w = [1.0, 5.0, 2.0, 1.0];
        // no comm cost == plain critical path
        assert_eq!(critical_path_with_edges(&g, &w, |_, _| 0.0), critical_path(&g, &w));
        // a uniform 1ms connector cost adds one hop per edge on the path
        assert_eq!(critical_path_with_edges(&g, &w, |_, _| 1.0), 9.0);
        // an expensive connector can flip which branch is critical
        let e = |s: usize, d: usize| if (s, d) == (0, 1) { 10.0 } else { 0.0 };
        assert_eq!(critical_path_with_edges(&g, &w, e), 17.0);
    }
}
