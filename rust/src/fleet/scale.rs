//! The allocator scale epoch — admission + heap water-filling over a
//! synthetic 10k–100k tenant fleet, with no simulators or learners in
//! the loop.
//!
//! The full fleet runner ([`super`]) carries a ladder-trace set and a
//! budgeted controller per tenant, which caps how far a smoke test can
//! push tenant counts. This module drives exactly the layers the
//! 100k-tenant epoch exercises — deterministic synthetic utility
//! curves, [`demand_cores`] reservations (through the
//! [`demand_cores_confident`] gate when `--demand-confidence` is set),
//! [`EpochAdmission::decide`], the [`allocate_v2`] heap water-fill over
//! a 2%-headroom budget, and the [`reserve_top_up`] pass that spends
//! the held-back cores — so CI can assert the epoch's invariants at
//! fleet scale in seconds:
//!
//! * granted quotas never exceed the pool,
//! * every utility that reaches the report is finite,
//! * `admitted + parked == tenants` every epoch,
//! * the JSON report is **byte-identical** across worker-thread counts.
//!
//! Thread-count independence is by construction: each tenant's curve is
//! a pure function of `(seed, tenant, epoch)` (worker threads only
//! split the tenant range; they never share RNG streams), and the
//! admission / allocation passes downstream of generation are serial
//! and index-ordered. The `alloc-epoch` CLI subcommand and the
//! `alloc-scale-smoke` CI job are thin wrappers over [`run`].

use anyhow::{ensure, Result};

use crate::scheduler::{
    allocate_v2, core_levels, demand_cores, demand_cores_confident, reserve_top_up,
    EpochAdmission,
};
use crate::util::json::Json;
use crate::util::Rng;

/// Shape of a scale run. `pool = tenants * cores_per_tenant`; with the
/// default 3 cores per tenant and demands that average above the even
/// share, every epoch parks a real fraction of the fleet, so admission
/// accounting is exercised rather than vacuously all-admitted.
#[derive(Debug, Clone)]
pub struct ScaleConfig {
    pub tenants: usize,
    pub epochs: usize,
    pub seed: u64,
    /// Worker threads for curve/demand generation. Never affects output.
    pub threads: usize,
    /// Requested ladder rung count (see [`core_levels`]).
    pub rungs: usize,
    pub cores_per_tenant: usize,
    /// Minimum per-rung observation count before a rung's utility counts
    /// toward the demand reservation ([`demand_cores_confident`]). `0`
    /// keeps the historical optimistic demand ([`demand_cores`])
    /// bit-for-bit; `> 0` draws synthetic observation counts from a
    /// salted RNG stream, so enabling it never perturbs a curve draw.
    pub demand_confidence: usize,
    /// Tenant shards. `1` is the legacy single-pool epoch; `> 1` runs
    /// the hierarchical coordinator over `mpsc` worker shards
    /// ([`super::shard::run_sharded`]) — byte-identical report by
    /// construction, which CI asserts.
    pub shards: usize,
}

impl Default for ScaleConfig {
    fn default() -> Self {
        ScaleConfig {
            tenants: 10_000,
            epochs: 3,
            seed: 42,
            threads: 1,
            rungs: 8,
            cores_per_tenant: 3,
            demand_confidence: 0,
            shards: 1,
        }
    }
}

/// Salt separating the observation-count stream from the curve stream:
/// turning `--demand-confidence` on must not perturb a single curve
/// draw, so observation counts fork from `seed ^ OBS_SALT` instead of
/// `seed`.
const OBS_SALT: u64 = 0x0b5e_c04e_7a11_e57a;

/// Synthetic per-rung observation counts for one tenant-epoch: plentiful
/// at the low rungs, sparse toward the top of the ladder (tenants spend
/// most frames near their grant, rarely at boost rungs) — so a
/// confidence gate of 2 actually masks a real fraction of satiation
/// rungs. Pure in `(seed, tenant, epoch)`, like the curves.
fn synth_obs(seed: u64, epoch: usize, tenant: usize, nlv: usize) -> Vec<u64> {
    let mut rng = Rng::new(seed ^ OBS_SALT).fork(((tenant as u64) << 32) | epoch as u64);
    (0..nlv).map(|l| rng.below(4 + (nlv - 1 - l) * 2) as u64).collect()
}

/// One tenant's epoch inputs: utility curve over the ladder plus its
/// core demand. Pure in `(seed, tenant, epoch)` — which is exactly why
/// the sharded tier ([`super::shard`]) can synthesize each shard's
/// slice on its own worker without moving the report.
pub(crate) fn synth_tenant(
    seed: u64,
    epoch: usize,
    tenant: usize,
    levels: &[usize],
    even: usize,
    min_obs: usize,
) -> (Vec<f64>, usize) {
    // 32-bit epoch field: epochs >= 2^16 must not bleed into the tenant
    // bits, or tenant T at epoch E would share a stream with tenant T+1.
    let mut rng = Rng::new(seed).fork(((tenant as u64) << 32) | epoch as u64);
    let nlv = levels.len();
    let reserve = |c: &[f64]| {
        if min_obs == 0 {
            demand_cores(c, levels, even)
        } else {
            let obs = synth_obs(seed, epoch, tenant, nlv);
            demand_cores_confident(c, levels, even, &obs, min_obs)
        }
    };
    // ~3% of tenants per epoch present a flat-zero curve (a starved or
    // freshly reset model): demand must fall back to the calibration
    // share, not to contentment.
    if rng.f64() < 0.03 {
        let c = vec![0.0; nlv];
        let d = reserve(&c);
        return (c, d);
    }
    // Non-decreasing curve that satiates at a random rung: random
    // positive increments up to `sat`, flat after, scaled to a random
    // top utility. Quantizing to 1/64 manufactures exact ties so the
    // allocator's tie-break order is exercised at scale.
    let sat = 1 + rng.below(nlv);
    let top = 0.3 + 0.7 * rng.f64();
    let mut acc = 0.0;
    let mut c = Vec::with_capacity(nlv);
    for l in 0..nlv {
        if l < sat {
            acc += 0.05 + rng.f64();
        }
        c.push(acc);
    }
    let mx = acc.max(1e-9);
    for v in &mut c {
        *v = (top * *v / mx * 64.0).round() / 64.0;
    }
    let d = reserve(&c);
    (c, d)
}

/// All tenants' curves and demands for one epoch, generated on
/// `threads` workers over contiguous tenant ranges. Chunking never
/// changes a value — only which thread computes it.
fn synth_epoch(
    cfg: &ScaleConfig,
    epoch: usize,
    levels: &[usize],
    even: usize,
) -> (Vec<Vec<f64>>, Vec<usize>) {
    let n = cfg.tenants;
    let threads = cfg.threads.max(1).min(n);
    let chunk = (n + threads - 1) / threads;
    let mut curves: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut demands: Vec<usize> = vec![0; n];
    std::thread::scope(|s| {
        for (ci, (cs, ds)) in curves
            .chunks_mut(chunk)
            .zip(demands.chunks_mut(chunk))
            .enumerate()
        {
            let base = ci * chunk;
            s.spawn(move || {
                for (off, (c, d)) in cs.iter_mut().zip(ds.iter_mut()).enumerate() {
                    let (curve, demand) = synth_tenant(
                        cfg.seed,
                        epoch,
                        base + off,
                        levels,
                        even,
                        cfg.demand_confidence,
                    );
                    *c = curve;
                    *d = demand;
                }
            });
        }
    });
    (curves, demands)
}

/// FNV-1a over the quota vector — a cheap fingerprint humans can eyeball
/// when diffing reports across thread counts or machines.
fn quota_fingerprint(quota: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &q in quota {
        for b in (q as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Run `cfg.epochs` reallocation epochs and return the JSON report.
///
/// The report deliberately omits the thread count: CI diffs the bytes
/// of `--threads 1/2/4` runs against each other.
pub fn run(cfg: &ScaleConfig) -> Result<Json> {
    ensure!(cfg.tenants > 0, "alloc-epoch needs at least one tenant");
    ensure!(cfg.epochs > 0, "alloc-epoch needs at least one epoch");
    if cfg.shards > 1 {
        return super::shard::run_sharded(cfg);
    }
    let n = cfg.tenants;
    let pool = n * cfg.cores_per_tenant.max(1);
    // Fairness reserve: the utility water-filler optimizes over the pool
    // minus a 2% headroom; [`reserve_top_up`] then spends the held-back
    // cores (against the full pool) seating under-served admitted
    // tenants at `min(reservation, even)` in priority order. Without the
    // holdback the top-up is provably a no-op — the water-filler's
    // even-share phase raise condition strictly dominates the top-up's,
    // so it reaches a fixed point the top-up cannot improve.
    let alloc_pool = pool - pool / 50;
    let levels = core_levels(pool, n, 1, cfg.rungs.max(2), 3.0);
    let even = (pool / n).max(1);
    // Three priority tiers, deterministic by index.
    let weights: Vec<f64> = (0..n)
        .map(|i| match i % 5 {
            0 => 4.0,
            1 | 2 => 2.0,
            _ => 1.0,
        })
        .collect();
    let mut adm = EpochAdmission::new(n, 4).with_hysteresis(even);
    let mut prev_rung = vec![0usize; n];
    let mut prev_admitted = vec![false; n];
    let mut epochs = Vec::with_capacity(cfg.epochs);
    for e in 0..cfg.epochs {
        let (curves, demands) = synth_epoch(cfg, e, &levels, even);
        let admitted = adm.decide(pool, &weights, &demands);
        let idx: Vec<usize> = (0..n).filter(|&i| admitted[i]).collect();
        let sub_curves: Vec<Vec<f64>> =
            idx.iter().map(|&i| curves[i].clone()).collect();
        let sub_weights: Vec<f64> = idx.iter().map(|&i| weights[i]).collect();
        // A tenant parked last epoch restarts at the floor rung.
        let sub_prev: Vec<usize> = idx
            .iter()
            .map(|&i| if prev_admitted[i] { prev_rung[i] } else { 0 })
            .collect();
        let mut grant =
            allocate_v2(&sub_curves, &levels, alloc_pool, &sub_weights, Some(&sub_prev), 0.02);
        // Reservation top-up (the fairness restoration [`reserve_top_up`]
        // documents): spend *idle* cores raising under-served admitted
        // tenants toward `min(reservation, even)`, priority order. All
        // slots are admitted in sub-index space by construction.
        let pre_top_up = grant.clone();
        let sub_res: Vec<usize> = idx.iter().map(|&i| demands[i]).collect();
        let all_admitted = vec![true; idx.len()];
        reserve_top_up(&mut grant, &levels, pool, &all_admitted, &sub_res, even, &sub_weights);
        let mut top_up = 0usize;
        for (s, (&g, &p)) in grant.iter().zip(&pre_top_up).enumerate() {
            ensure!(
                g >= p,
                "tenant {} epoch {e}: top-up reduced rung {p} -> {g}",
                idx[s]
            );
            top_up += levels[g] - levels[p];
        }
        let mut quota = vec![0usize; n];
        let mut util_sum = 0.0;
        let mut moved = 0usize;
        for (s, &i) in idx.iter().enumerate() {
            quota[i] = levels[grant[s]];
            let u = sub_curves[s][grant[s]];
            ensure!(u.is_finite(), "tenant {i} epoch {e}: non-finite utility {u}");
            util_sum += weights[i] * u;
            if prev_admitted[i] && grant[s] != prev_rung[i] {
                moved += 1;
            }
            prev_rung[i] = grant[s];
        }
        let used: usize = quota.iter().sum();
        ensure!(
            used <= pool,
            "epoch {e}: granted {used} cores from a pool of {pool}"
        );
        let parked = n - idx.len();
        ensure!(idx.len() + parked == n, "epoch {e}: admission accounting");
        epochs.push(
            Json::obj()
                .put("epoch", e)
                .put("admitted", idx.len())
                .put("parked", parked)
                .put("used_cores", used)
                .put("top_up_cores", top_up)
                .put("moved_tenants", moved)
                .put("weighted_utility", util_sum)
                .put("quota_fingerprint", format!("{:016x}", quota_fingerprint(&quota))),
        );
        prev_admitted = admitted;
    }
    Ok(Json::obj()
        .put("tenants", n)
        .put("pool", pool)
        .put("seed", cfg.seed)
        .put("demand_confidence", cfg.demand_confidence)
        .put(
            "levels",
            Json::from_f64_slice(&levels.iter().map(|&l| l as f64).collect::<Vec<_>>()),
        )
        .put("epochs", Json::Arr(epochs)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_with_threads(threads: usize) -> String {
        let cfg = ScaleConfig { tenants: 600, epochs: 3, threads, ..Default::default() };
        run(&cfg).unwrap().to_string()
    }

    #[test]
    fn report_byte_identical_across_threads() {
        let one = run_with_threads(1);
        let two = run_with_threads(2);
        let four = run_with_threads(4);
        assert_eq!(one, two, "1-thread vs 2-thread report drift");
        assert_eq!(one, four, "1-thread vs 4-thread report drift");
    }

    #[test]
    fn report_byte_identical_across_shards() {
        // The tentpole determinism bar: the hierarchical coordinator
        // over mpsc worker shards reproduces the single-pool report
        // byte-for-byte (mirror-validated in
        // python/tests/test_shard_mirror.py), and S=1 *is* the legacy
        // path — `run` only dispatches to the shard tier for S > 1.
        let single = run_with_threads(1);
        for shards in [2usize, 4] {
            let cfg = ScaleConfig { tenants: 600, epochs: 3, shards, ..Default::default() };
            assert_eq!(
                run(&cfg).unwrap().to_string(),
                single,
                "{shards}-shard report drifts from the single pool"
            );
        }
    }

    #[test]
    fn sharded_report_survives_the_confidence_gate() {
        // Demand gating changes admission packing; the shard summaries
        // must carry the gated demands, not recompute optimistic ones.
        let conf =
            ScaleConfig { tenants: 400, epochs: 3, demand_confidence: 2, ..Default::default() };
        let want = run(&conf).unwrap().to_string();
        let sharded = ScaleConfig { shards: 3, ..conf };
        assert_eq!(run(&sharded).unwrap().to_string(), want);
    }

    #[test]
    fn epoch_invariants_hold() {
        let cfg = ScaleConfig { tenants: 400, epochs: 4, ..Default::default() };
        let report = run(&cfg).unwrap();
        let pool = report.req("pool").unwrap().as_usize().unwrap();
        let epochs = report.req("epochs").unwrap().as_arr().unwrap();
        assert_eq!(epochs.len(), 4);
        for e in epochs {
            let admitted = e.req("admitted").unwrap().as_usize().unwrap();
            let parked = e.req("parked").unwrap().as_usize().unwrap();
            let used = e.req("used_cores").unwrap().as_usize().unwrap();
            assert_eq!(admitted + parked, 400);
            assert!(used <= pool, "used {used} > pool {pool}");
            assert!(admitted > 0, "top-ranked tenant is always admitted");
            assert!(
                e.req("weighted_utility").unwrap().as_f64().unwrap().is_finite()
            );
        }
    }

    #[test]
    fn top_up_spends_the_fairness_reserve() {
        // Mirror-validated (python/tests/test_scale_epoch_mirror.py):
        // with the 2% holdback, demand pressure above the even share
        // leaves under-served tenants every epoch, so the top-up always
        // finds work — and never pushes usage past the pool.
        for tenants in [400, 500, 600] {
            let cfg = ScaleConfig { tenants, epochs: 3, ..Default::default() };
            let report = run(&cfg).unwrap();
            let pool = report.req("pool").unwrap().as_usize().unwrap();
            for e in report.req("epochs").unwrap().as_arr().unwrap() {
                let top_up = e.req("top_up_cores").unwrap().as_usize().unwrap();
                let used = e.req("used_cores").unwrap().as_usize().unwrap();
                assert!(top_up > 0, "{tenants} tenants: top-up never fired: {e}");
                assert!(used <= pool, "{tenants} tenants: used {used} > pool {pool}");
            }
        }
    }

    #[test]
    fn demand_confidence_gates_reservations() {
        // Mirror-validated: masking unconfident rungs changes demands,
        // which changes admission packing and the quota fingerprints —
        // while staying byte-identical across worker-thread counts.
        let base = ScaleConfig { tenants: 400, epochs: 3, ..Default::default() };
        let conf =
            ScaleConfig { tenants: 400, epochs: 3, demand_confidence: 2, ..Default::default() };
        let base_rep = run(&base).unwrap().to_string();
        let conf_rep = run(&conf).unwrap().to_string();
        assert_ne!(base_rep, conf_rep, "confidence gate never changed the report");
        let conf4 = ScaleConfig { threads: 4, ..conf };
        assert_eq!(
            conf_rep,
            run(&conf4).unwrap().to_string(),
            "confidence-gated report drifts across thread counts"
        );
    }

    #[test]
    fn parking_actually_happens() {
        // With 3 cores/tenant and demands that average above the even
        // share, at least one epoch must park somebody — otherwise the
        // smoke is vacuous.
        let cfg = ScaleConfig { tenants: 500, epochs: 3, ..Default::default() };
        let report = run(&cfg).unwrap();
        let parked: usize = report
            .req("epochs")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.req("parked").unwrap().as_usize().unwrap())
            .sum();
        assert!(parked > 0, "scale smoke never exercised admission parking");
    }
}
