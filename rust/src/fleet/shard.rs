//! The `mpsc` shard tier: each tenant shard runs its
//! [`TenantShard`] server on a dedicated worker thread, exchanging
//! [`Directive`]/[`Reply`] pairs with the global coordinator over
//! `std::sync::mpsc` channels — the threaded implementation of the
//! [`ShardChannel`] seam (the in-process tier is
//! [`InlineChannel`]). A multi-process tier would replace this module's
//! transport with a socket codec and change nothing above the trait,
//! the same layering timely-dataflow uses for its thread/process
//! allocators.
//!
//! [`run_sharded`] is the sharded twin of [`scale::run`]: same
//! synthetic fleet, same admission/water-fill/top-up epoch, but every
//! per-tenant computation (curve synthesis, admission bucketing, heap
//! drains, statistics) happens on the owning shard's worker, and only
//! the token-protocol summaries cross threads. The report is
//! byte-identical to the single-pool path for every shard count —
//! `--shards` is a topology knob, not a semantics knob. See
//! `docs/DETERMINISM.md` for why that bar is load-bearing and
//! `docs/ARCHITECTURE.md` for where this tier sits in the stack.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{ensure, Result};

use crate::scheduler::coordinator::{
    decide_sharded, shard_bounds, top_up_sharded, waterfill_sharded, Directive, InlineChannel,
    Reply, ShardChannel, TenantShard,
};
use crate::scheduler::core_levels;
use crate::util::json::Json;

use super::scale::{self, synth_tenant, ScaleConfig};

/// How long the coordinator waits on a shard worker before declaring
/// the protocol wedged. Generous: a shard's largest unit of work (a
/// full heap drain at 100k tenants) is milliseconds.
const SHARD_REPLY_TIMEOUT: Duration = Duration::from_secs(300);

/// Priority weight of a global tenant index — the same three-tier
/// pattern [`scale::run`] builds, computed shard-side so weight vectors
/// never cross the channel.
fn tenant_weight(i: usize) -> f64 {
    match i % 5 {
        0 => 4.0,
        1 | 2 => 2.0,
        _ => 1.0,
    }
}

/// A [`ShardChannel`] whose [`TenantShard`] server lives on a worker
/// thread. Directives are fire-and-forget at `send`; the worker queues
/// exactly one reply per directive, so coordinator broadcasts overlap
/// shard work across all workers.
pub struct MpscShardChannel {
    tx: Sender<Directive>,
    rx: Receiver<Reply>,
    handle: Option<JoinHandle<()>>,
}

impl MpscShardChannel {
    /// Spawn the worker for shard `sid` owning tenants `[lo, hi)`.
    /// `Begin { epoch }` directives are handled transport-side: the
    /// worker synthesizes its slice of the fleet (pure per-tenant
    /// functions of `(seed, tenant, epoch)`, so shard topology cannot
    /// move a value) and loads it into the shard server.
    pub fn spawn(
        sid: usize,
        lo: usize,
        hi: usize,
        cfg: &ScaleConfig,
        levels: Vec<usize>,
        even: usize,
        hysteresis: usize,
    ) -> Self {
        let (tx, dir_rx) = channel::<Directive>();
        let (reply_tx, rx) = channel::<Reply>();
        let seed = cfg.seed;
        let min_obs = cfg.demand_confidence;
        let handle = std::thread::spawn(move || {
            let mut shard = TenantShard::new(sid, lo, hi, 4, hysteresis);
            while let Ok(d) = dir_rx.recv() {
                let reply = match d {
                    Directive::Begin { epoch } => {
                        let mut curves = Vec::with_capacity(hi - lo);
                        let mut demands = Vec::with_capacity(hi - lo);
                        for t in lo..hi {
                            let (c, d) = synth_tenant(seed, epoch, t, &levels, even, min_obs);
                            curves.push(c);
                            demands.push(d);
                        }
                        let weights = (lo..hi).map(tenant_weight).collect();
                        shard.load_epoch(curves, demands, weights);
                        Reply::Loaded
                    }
                    Directive::Shutdown => {
                        let _ = reply_tx.send(Reply::Done);
                        break;
                    }
                    other => shard.handle(other),
                };
                if reply_tx.send(reply).is_err() {
                    break;
                }
            }
        });
        MpscShardChannel { tx, rx, handle: Some(handle) }
    }

    /// Shut the worker down and join it. Idempotent; called by the
    /// epoch driver on success (error paths just drop the channel,
    /// which ends the worker's receive loop).
    pub fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = self.tx.send(Directive::Shutdown);
            while let Ok(r) = self.rx.recv_timeout(SHARD_REPLY_TIMEOUT) {
                if matches!(r, Reply::Done) {
                    break;
                }
            }
            let _ = h.join();
        }
    }
}

impl ShardChannel for MpscShardChannel {
    fn send(&mut self, d: Directive) {
        self.tx.send(d).expect("shard worker hung up mid-protocol");
    }

    fn recv(&mut self) -> Reply {
        self.rx
            .recv_timeout(SHARD_REPLY_TIMEOUT)
            // detlint: allow(unwrap) — every directive owes one reply; a timeout means the worker died or wedged
            .expect("shard worker failed to reply within the protocol timeout")
    }
}

/// The sharded reallocation epoch: [`scale::run`] with tenants
/// partitioned across `cfg.shards` mpsc workers and the global
/// coordinator driving admission, both water-fill phases, the
/// reservation top-up, and the statistics fold through the token
/// protocol of [`crate::scheduler::coordinator`]. Byte-identical to the
/// single-pool report for every shard count; `cfg.threads` is ignored
/// here because synthesis parallelism comes from the shard workers
/// themselves (and can never move a value either way).
pub fn run_sharded(cfg: &ScaleConfig) -> Result<Json> {
    ensure!(cfg.tenants > 0, "alloc-epoch needs at least one tenant");
    ensure!(cfg.epochs > 0, "alloc-epoch needs at least one epoch");
    let n = cfg.tenants;
    let pool = n * cfg.cores_per_tenant.max(1);
    // Same fairness holdback as the single-pool epoch: water-fill over
    // 98% of the pool, reservation top-up against the full pool.
    let alloc_pool = pool - pool / 50;
    let levels = core_levels(pool, n, 1, cfg.rungs.max(2), 3.0);
    let even = (pool / n).max(1);
    let bounds = shard_bounds(n, cfg.shards);
    let mut channels: Vec<MpscShardChannel> = bounds
        .iter()
        .enumerate()
        .map(|(sid, &(lo, hi))| {
            MpscShardChannel::spawn(sid, lo, hi, cfg, levels.clone(), even, even)
        })
        .collect();
    let mut epochs = Vec::with_capacity(cfg.epochs);
    for e in 0..cfg.epochs {
        // Parallel synthesis: every worker builds its slice at once.
        for ch in channels.iter_mut() {
            ch.send(Directive::Begin { epoch: e });
        }
        for ch in channels.iter_mut() {
            ensure!(matches!(ch.recv(), Reply::Loaded), "epoch {e}: shard failed to load");
        }
        let decision = decide_sharded(&mut channels, pool, 4);
        let n_adm = decision.flags.iter().filter(|&&a| a).count();
        ensure!(n_adm > 0, "epoch {e}: admission admitted nobody");
        for ch in channels.iter_mut() {
            ch.send(Directive::InstallFillLocal { levels: levels.clone(), hysteresis: 0.02 });
        }
        for ch in channels.iter_mut() {
            ensure!(matches!(ch.recv(), Reply::FillInstalled), "epoch {e}: fill install failed");
        }
        let floor = n_adm * levels[0];
        ensure!(floor <= alloc_pool, "epoch {e}: floor rungs oversubscribe the fill budget");
        let used = waterfill_sharded(&mut channels, floor, alloc_pool, alloc_pool / n_adm);
        top_up_sharded(&mut channels, &decision.tiers, even, pool, used);
        // Statistics fold, shard-major: the chained FNV fingerprint and
        // the float utility sum accumulate in exactly the single-pool
        // index order, so the report bytes cannot move.
        let mut fp: u64 = 0xcbf2_9ce4_8422_2325;
        let mut util_sum = 0.0f64;
        let (mut admitted, mut used_cores, mut top_up, mut moved) =
            (0usize, 0usize, 0usize, 0usize);
        for ch in channels.iter_mut() {
            ch.send(Directive::Stats { fp, util: util_sum });
            match ch.recv() {
                Reply::Stats { admitted: a, used: u, top_up: t, moved: m, util, fp: h } => {
                    admitted += a;
                    used_cores += u;
                    top_up += t;
                    moved += m;
                    util_sum = util;
                    fp = h;
                }
                other => anyhow::bail!("epoch {e}: expected Stats reply, got {other:?}"),
            }
        }
        ensure!(admitted == n_adm, "epoch {e}: admission/fill accounting drift");
        ensure!(used_cores <= pool, "epoch {e}: granted {used_cores} cores from a pool of {pool}");
        let parked = n - admitted;
        epochs.push(
            Json::obj()
                .put("epoch", e)
                .put("admitted", admitted)
                .put("parked", parked)
                .put("used_cores", used_cores)
                .put("top_up_cores", top_up)
                .put("moved_tenants", moved)
                .put("weighted_utility", util_sum)
                .put("quota_fingerprint", format!("{fp:016x}")),
        );
    }
    for ch in channels.iter_mut() {
        ch.join();
    }
    Ok(Json::obj()
        .put("tenants", n)
        .put("pool", pool)
        .put("seed", cfg.seed)
        .put("demand_confidence", cfg.demand_confidence)
        .put(
            "levels",
            Json::from_f64_slice(&levels.iter().map(|&l| l as f64).collect::<Vec<_>>()),
        )
        .put("epochs", Json::Arr(epochs)))
}

/// In-process shard set for the fleet scheduler's fill tier: builds one
/// [`InlineChannel`] per contiguous slice of the admitted sub-instance.
/// Kept here (rather than in the coordinator) so the fleet runner has a
/// single import point for shard topology.
pub fn inline_shards(napps: usize, shards: usize) -> Vec<InlineChannel> {
    shard_bounds(napps, shards)
        .iter()
        .enumerate()
        .map(|(sid, &(lo, hi))| InlineChannel::new(TenantShard::new(sid, lo, hi, 1, 0)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mpsc_channel_round_trips_the_protocol() {
        let cfg = ScaleConfig { tenants: 10, epochs: 1, ..Default::default() };
        let levels = vec![1usize, 2, 4];
        let mut ch = MpscShardChannel::spawn(0, 0, 10, &cfg, levels, 3, 0);
        ch.send(Directive::Begin { epoch: 0 });
        assert!(matches!(ch.recv(), Reply::Loaded));
        ch.send(Directive::Summarize);
        match ch.recv() {
            Reply::Summary(s) => {
                let members: usize = s.buckets.iter().map(|&(_, c, _)| c).sum();
                assert_eq!(members, 10, "every tenant lands in exactly one bucket");
            }
            other => panic!("expected Summary, got {other:?}"),
        }
        ch.join();
    }

    #[test]
    fn worker_exits_on_channel_drop() {
        let cfg = ScaleConfig { tenants: 4, epochs: 1, ..Default::default() };
        let ch = MpscShardChannel::spawn(0, 0, 4, &cfg, vec![1, 2], 1, 0);
        let handle = {
            let mut ch = ch;
            ch.handle.take()
            // channel endpoints drop here: the worker's recv errors out
        };
        handle.expect("spawn sets the handle").join().expect("worker exits cleanly");
    }

    #[test]
    fn shard_count_never_moves_the_report() {
        let base = ScaleConfig { tenants: 500, epochs: 2, ..Default::default() };
        let want = scale::run(&base).expect("single pool runs").to_string();
        for shards in [2usize, 3, 5] {
            let cfg = ScaleConfig { shards, ..base.clone() };
            let got = run_sharded(&cfg).expect("sharded run").to_string();
            assert_eq!(got, want, "{shards} shards drift from the single pool");
        }
    }
}
