//! Fleet runner — tune many generated applications concurrently.
//!
//! The paper evaluates one tuner on one application at a time; a
//! production deployment runs *fleets* of perception pipelines side by
//! side. This module is that scale/stress path: it splits the simulated
//! cluster evenly across N procedurally generated apps
//! ([`workloads`](crate::workloads)), tunes each with its own ε-greedy
//! controller on its own OS thread, and aggregates the per-app
//! [`PolicyStats`] (fidelity vs. the clairvoyant oracle, constraint
//! violations, convergence frames) into a single JSON report.
//!
//! Results are deterministic for a given `(seed, apps, frames)` triple
//! regardless of thread count: every app's pipeline, traces and
//! controller derive their randomness from `seed + index` alone, and the
//! report is assembled by index.
//!
//! The controller targets `bound × bound_headroom` while violations are
//! scored against the spec bound itself — standard SLO headroom so the
//! learned operating point does not sit exactly on the constraint where
//! measurement noise pushes half the frames over. On top of that, the
//! fleet enables the controller's per-action empirical cost blend
//! ([`EpsGreedyController::with_empirical_blend`]): across hundreds of
//! generated apps, some action space always contains a high-fidelity
//! config the polynomial model persistently under-predicts, and blending
//! in each action's own observed latency keeps such configs from being
//! exploited into chronic violations.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::metrics::PolicyStats;
use crate::runtime::native::NativeBackend;
use crate::simulator::Cluster;
use crate::trace::TraceSet;
use crate::tuner::policy::oracle_best;
use crate::tuner::{EpsGreedyController, TunerConfig};
use crate::util::json::Json;
use crate::workloads::{self, WorkloadConfig};

/// Post-warmup bound-met fraction every app is expected to clear.
pub const FLEET_SLO_FRAC: f64 = 0.80;

/// Fleet run configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of generated applications tuned concurrently.
    pub apps: usize,
    /// Frames each controller runs.
    pub frames: usize,
    /// Master seed; app `i` derives everything from `seed + i`.
    pub seed: u64,
    /// Size of each app's trace-based action space.
    pub configs_per_app: usize,
    /// Exploration rate; `None` → the paper's 1/√T rule.
    pub epsilon: Option<f64>,
    pub warmup_frames: usize,
    /// The controller solves against `bound × headroom` (violations are
    /// still scored against the spec bound).
    pub bound_headroom: f64,
    /// Shrinkage count of the controller's per-action empirical cost
    /// blend (see [`EpsGreedyController::with_empirical_blend`]); 0 runs
    /// the paper's pure-model exploit.
    pub empirical_blend_k: f64,
    /// Worker OS threads; 0 → one per available core, capped at `apps`.
    pub threads: usize,
    /// The shared cluster divided across the fleet.
    pub cluster: Cluster,
    /// Generation envelope for the workloads.
    pub workload: WorkloadConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            apps: 4,
            frames: 500,
            seed: 7,
            configs_per_app: 24,
            epsilon: None,
            warmup_frames: 20,
            bound_headroom: 0.90,
            empirical_blend_k: 8.0,
            threads: 0,
            cluster: Cluster::default(),
            workload: WorkloadConfig::default(),
        }
    }
}

/// Outcome of tuning one generated app.
#[derive(Debug, Clone)]
pub struct AppReport {
    pub index: usize,
    pub name: String,
    pub seed: u64,
    pub stages: usize,
    pub knobs: usize,
    pub branches: usize,
    /// The calibrated latency bound L (ms) violations are scored against.
    pub bound_ms: f64,
    pub avg_fidelity: f64,
    pub oracle_fidelity: f64,
    /// avg_fidelity / oracle_fidelity (the paper's 90%-of-optimum axis).
    pub fidelity_vs_oracle: f64,
    pub avg_violation_ms: f64,
    pub max_violation_ms: f64,
    pub violation_rate: f64,
    /// Fraction of post-warmup frames under the bound (the fleet SLO).
    pub post_warmup_bound_met_frac: f64,
    /// Candidate actions whose trace meets the bound on ≥95% of frames —
    /// how much robustly feasible room the controller had to work with.
    pub robust_feasible_actions: usize,
    /// First frame whose trailing-50 mean fidelity reached 90% of oracle.
    pub convergence_frame: Option<usize>,
    pub explore_frames: usize,
    /// Raw accumulator (kept for fleet-wide merging).
    pub stats: PolicyStats,
}

impl AppReport {
    pub fn to_json(&self) -> Json {
        let conv = match self.convergence_frame {
            Some(f) => Json::from(f),
            None => Json::Null,
        };
        Json::obj()
            .put("index", self.index)
            .put("name", self.name.as_str())
            .put("seed", self.seed)
            .put("stages", self.stages)
            .put("knobs", self.knobs)
            .put("branches", self.branches)
            .put("bound_ms", self.bound_ms)
            .put("avg_fidelity", self.avg_fidelity)
            .put("oracle_fidelity", self.oracle_fidelity)
            .put("fidelity_vs_oracle", self.fidelity_vs_oracle)
            .put("avg_violation_ms", self.avg_violation_ms)
            .put("max_violation_ms", self.max_violation_ms)
            .put("violation_rate", self.violation_rate)
            .put("post_warmup_bound_met_frac", self.post_warmup_bound_met_frac)
            .put("robust_feasible_actions", self.robust_feasible_actions)
            .put("convergence_frame", conv)
            .put("explore_frames", self.explore_frames)
    }
}

/// Aggregated fleet outcome.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub apps: Vec<AppReport>,
    pub frames: usize,
    pub seed: u64,
    pub epsilon: f64,
    pub warmup_frames: usize,
    pub bound_headroom: f64,
    pub cores_per_app: usize,
    pub avg_fidelity_vs_oracle: f64,
    pub min_bound_met_frac: f64,
    pub apps_meeting_slo: usize,
    pub merged: PolicyStats,
}

impl FleetReport {
    pub fn all_apps_meet_slo(&self) -> bool {
        self.apps_meeting_slo == self.apps.len()
    }

    pub fn to_json(&self) -> Json {
        let details: Vec<Json> = self.apps.iter().map(|a| a.to_json()).collect();
        Json::obj()
            .put("apps", self.apps.len())
            .put("frames", self.frames)
            .put("seed", self.seed)
            .put("epsilon", self.epsilon)
            .put("warmup_frames", self.warmup_frames)
            .put("bound_headroom", self.bound_headroom)
            .put("cores_per_app", self.cores_per_app)
            .put(
                "aggregate",
                Json::obj()
                    .put("avg_fidelity_vs_oracle", self.avg_fidelity_vs_oracle)
                    .put("min_post_warmup_bound_met_frac", self.min_bound_met_frac)
                    .put("slo_frac", FLEET_SLO_FRAC)
                    .put("apps_meeting_slo", self.apps_meeting_slo)
                    .put("all_apps_meet_slo", self.all_apps_meet_slo())
                    .put("avg_violation_ms", self.merged.avg_violation_ms())
                    .put("max_violation_ms", self.merged.max_violation_ms())
                    .put("violation_rate", self.merged.violation_rate()),
            )
            .put("apps_detail", Json::Arr(details))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing fleet report {}", path.display()))?;
        Ok(())
    }
}

/// Each app's even slice of the shared cluster: exactly
/// `total_cores / apps` cores (expressed as one virtual server, so the
/// fleet never oversubscribes the shared budget), floored at one physical
/// server's worth — fleets larger than the server count deliberately
/// co-tenant at that floor.
pub fn cluster_slice(total: &Cluster, apps: usize) -> Cluster {
    let per_app_cores = (total.total_cores() / apps.max(1)).max(total.cores_per_server);
    Cluster {
        servers: 1,
        cores_per_server: per_app_cores,
        comm_ms_per_frame: total.comm_ms_per_frame,
    }
}

/// Generate, trace and tune fleet member `index`. Pure function of
/// `(cfg, index)` — this is what makes multi-threaded runs reproducible.
pub fn run_app(cfg: &FleetConfig, index: usize) -> AppReport {
    let slice = cluster_slice(&cfg.cluster, cfg.apps);
    let app_seed = cfg.seed.wrapping_add(index as u64);
    let app = workloads::generate_on(app_seed, &cfg.workload, &slice);
    let bound = app.spec.latency_bounds_ms[0];

    let trace_frames = cfg.frames.max(100);
    let traces = TraceSet::generate_on(
        &app,
        &slice,
        cfg.configs_per_app,
        trace_frames,
        app_seed ^ 0x7A3E_5EED,
    );

    let eps = cfg
        .epsilon
        .unwrap_or_else(|| TunerConfig::epsilon_for_horizon(cfg.frames.max(1)));
    let tuner_cfg = TunerConfig {
        epsilon: eps,
        bound_ms: bound * cfg.bound_headroom,
        warmup_frames: cfg.warmup_frames,
    };
    let backend = NativeBackend::structured(&app.spec);
    let mut ctl = EpsGreedyController::new(
        &app.spec,
        &traces,
        Box::new(backend),
        tuner_cfg,
        app_seed ^ 0x00C0_FFEE,
    )
    .with_empirical_blend(cfg.empirical_blend_k);
    let out = ctl.run(cfg.frames);
    let oracle = oracle_best(&traces, cfg.frames, bound);

    // violations scored against the spec bound, not the headroom target
    let mut stats = PolicyStats::new();
    for s in &out.steps {
        stats.observe(s.reward, s.latency_ms, bound);
    }
    let oracle_fid = oracle.avg_reward.max(1e-9);
    AppReport {
        index,
        name: app.spec.name.clone(),
        seed: app_seed,
        stages: app.spec.stages.len(),
        knobs: app.spec.num_vars(),
        branches: app.spec.branches().len(),
        bound_ms: bound,
        avg_fidelity: stats.avg_reward(),
        oracle_fidelity: oracle.avg_reward,
        fidelity_vs_oracle: stats.avg_reward() / oracle_fid,
        avg_violation_ms: stats.avg_violation_ms(),
        max_violation_ms: stats.max_violation_ms(),
        violation_rate: stats.violation_rate(),
        post_warmup_bound_met_frac: out.bound_met_frac_after(cfg.warmup_frames, bound),
        robust_feasible_actions: traces
            .traces
            .iter()
            .filter(|t| t.frac_under(bound) >= 0.95)
            .count(),
        convergence_frame: out.convergence_frame(50, 0.9 * oracle.avg_reward),
        explore_frames: out.explore_frames,
        stats,
    }
}

/// Run the whole fleet across OS threads and aggregate.
pub fn run_fleet(cfg: &FleetConfig) -> FleetReport {
    assert!(cfg.apps > 0, "fleet needs at least one app");
    assert!(cfg.frames > 0, "fleet needs at least one frame");
    assert!(
        cfg.warmup_frames < cfg.frames,
        "warmup ({}) must leave post-warmup frames to score the SLO on ({})",
        cfg.warmup_frames,
        cfg.frames
    );
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.threads
    }
    .clamp(1, cfg.apps);

    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<AppReport>>> =
        Mutex::new((0..cfg.apps).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= cfg.apps {
                    break;
                }
                let report = run_app(cfg, i);
                slots.lock().unwrap()[i] = Some(report);
            });
        }
    });
    let apps: Vec<AppReport> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every fleet slot is filled before the scope ends"))
        .collect();

    let n = apps.len() as f64;
    let avg_ratio = apps.iter().map(|a| a.fidelity_vs_oracle).sum::<f64>() / n;
    let min_met = apps
        .iter()
        .map(|a| a.post_warmup_bound_met_frac)
        .fold(f64::INFINITY, f64::min);
    let meeting = apps
        .iter()
        .filter(|a| a.post_warmup_bound_met_frac >= FLEET_SLO_FRAC)
        .count();
    let mut merged = PolicyStats::new();
    for a in &apps {
        merged.merge(&a.stats);
    }
    FleetReport {
        frames: cfg.frames,
        seed: cfg.seed,
        epsilon: cfg
            .epsilon
            .unwrap_or_else(|| TunerConfig::epsilon_for_horizon(cfg.frames)),
        warmup_frames: cfg.warmup_frames,
        bound_headroom: cfg.bound_headroom,
        cores_per_app: cluster_slice(&cfg.cluster, cfg.apps).total_cores(),
        avg_fidelity_vs_oracle: avg_ratio,
        min_bound_met_frac: min_met,
        apps_meeting_slo: meeting,
        merged,
        apps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FleetConfig {
        FleetConfig {
            apps: 3,
            frames: 120,
            seed: 42,
            configs_per_app: 10,
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn cluster_slice_splits_evenly() {
        let total = Cluster::default(); // 15 x 8 = 120 cores
        assert_eq!(cluster_slice(&total, 8).total_cores(), 15);
        assert_eq!(cluster_slice(&total, 1).total_cores(), 120);
        // the fleet never oversubscribes the shared budget ...
        for apps in 1..=15 {
            assert!(cluster_slice(&total, apps).total_cores() * apps <= 120, "{apps}");
        }
        // ... until fleets exceed the server count, which co-tenant at
        // one server's worth each
        assert_eq!(cluster_slice(&total, 1000).total_cores(), 8);
    }

    #[test]
    #[should_panic(expected = "warmup")]
    fn warmup_exceeding_frames_is_rejected() {
        let mut cfg = small_cfg();
        cfg.warmup_frames = cfg.frames;
        run_fleet(&cfg);
    }

    #[test]
    fn fleet_runs_every_app() {
        let report = run_fleet(&small_cfg());
        assert_eq!(report.apps.len(), 3);
        for (i, a) in report.apps.iter().enumerate() {
            assert_eq!(a.index, i);
            assert_eq!(a.seed, 42 + i as u64);
            assert!(a.bound_ms > 0.0);
            assert!((0.0..=1.0).contains(&a.post_warmup_bound_met_frac));
            assert!((0.0..=1.0).contains(&a.violation_rate));
            assert!(a.avg_fidelity > 0.0, "app {i} learned nothing");
        }
        assert!(report.avg_fidelity_vs_oracle > 0.0);
        assert!(report.min_bound_met_frac <= 1.0);
    }

    #[test]
    fn report_json_shape() {
        let report = run_fleet(&small_cfg());
        let j = report.to_json();
        assert_eq!(j.req("apps").unwrap().as_usize().unwrap(), 3);
        let agg = j.req("aggregate").unwrap();
        assert!(agg.req("min_post_warmup_bound_met_frac").unwrap().as_f64().is_ok());
        let details = j.req("apps_detail").unwrap().as_arr().unwrap();
        assert_eq!(details.len(), 3);
        assert_eq!(details[1].req("index").unwrap().as_usize().unwrap(), 1);
        // round-trips through the in-tree parser
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req("seed").unwrap().as_u64().unwrap(), 42);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut a_cfg = small_cfg();
        a_cfg.threads = 1;
        let mut b_cfg = small_cfg();
        b_cfg.threads = 3;
        let a = run_fleet(&a_cfg);
        let b = run_fleet(&b_cfg);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}
