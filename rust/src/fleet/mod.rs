//! Fleet runner — many co-tenant applications tuned against ONE shared,
//! contended cluster.
//!
//! The paper evaluates one tuner on one application at a time; a
//! production deployment runs *fleets* of perception pipelines side by
//! side. PR 1's fleet gave every app a static even slice of the cluster;
//! this version replaces the slices with a fleet-level scheduler
//! ([`scheduler`](crate::scheduler)): all apps draw from a single
//! [`SharedCluster`] core pool, and every reallocation epoch the
//! scheduler re-divides the cores by marginal utility — each app's
//! learned latency model answers "what fidelity could you hold at k
//! cores?" ([`BudgetedController::utility_at`]) and the next core chunk
//! goes to whoever buys the most fidelity with it, above a fairness
//! floor. [`FleetMode::Static`] pins every app at the even share through
//! the same machinery, which makes the two modes byte-comparable: same
//! apps, same ladder traces, same controllers — only the allocation
//! policy differs.
//!
//! Results are deterministic for a given `(seed, apps, frames)` triple
//! regardless of worker-thread count: every app's pipeline, ladder traces
//! and controller derive their randomness from `seed + index` alone;
//! apps are pinned to workers (`index % threads`) so controller state
//! never migrates; and each epoch's allocation is a pure function of the
//! per-app utility curves gathered at the previous epoch's end.
//!
//! Heterogeneous fleets (`heterogeneous: true`) alternate
//! [`AppProfile::Light`] / [`AppProfile::Heavy`] generated apps, and
//! `load_shift_frame` scripts a synchronized mid-run cost jump across the
//! heavy apps — the scenario in which dynamic reallocation demonstrably
//! beats the static even slice (see `rust/tests/scheduler_fleet.rs`).
//!
//! Scheduler v2 ([`SchedulerConfig`]) layers three production behaviors
//! on top: priority weights (tenant tiers) tilt the water-filling pass,
//! the hysteresis term pins each app to its incumbent quota unless the
//! predicted gain clears the migration penalty (churn is tracked per
//! epoch in [`AllocationFrame::churn_cores`] and aggregated in
//! [`FleetReport::core_churn`]), and admission control parks the
//! lowest-priority apps — zero cores, frames dropped and counted —
//! whenever `floor × apps` exceeds the pool, switching the whole run to
//! exact fairness-floor accounting (sub-stage-count quotas charge a
//! time-multiplexing latency multiplier in the traces, the calibration
//! probes, and the controller's predictions alike).
//!
//! Scheduler v3 ([`SchedulerConfig::admission_epoch`]) makes admission
//! *epoch-granular*: every epoch the fleet re-decides who runs from the
//! tenants' learned demands ([`scheduler::demand_cores`]), re-admits parked
//! tenants when the pool frees up (e.g. after a scripted load drop),
//! rotates parking among equal-priority tenants under a starvation bound,
//! and applies scripted mid-run tier shifts
//! ([`SchedulerConfig::tier_shift`]). Parking is no longer a run-level
//! fast path: every tenant keeps its ladder traces and controller across
//! parked epochs, so a re-admitted tenant resumes with a *warm* model.
//! Reports account per-epoch — [`AppReport::parked_epochs`],
//! [`AppReport::admitted_frames`], [`AppReport::scored_frames`] — and the
//! SLO is scored over the frames a tenant actually ran
//! ([`FleetReport::all_apps_meet_slo`]), so a tenant parked for 2 of 100
//! epochs is judged on the 98 it ran instead of being silently excluded.
//!
//! [`BudgetedController::utility_at`]:
//!     crate::tuner::BudgetedController::utility_at

pub mod scale;
pub mod shard;

use std::path::Path;
use std::sync::mpsc::channel;

use anyhow::{Context, Result};

use crate::metrics::PolicyStats;
use crate::obs::{self, EpochLatencies, Event, EventKind, TraceCollector};
use crate::runtime::native::NativeBackend;
use crate::scheduler::coordinator::{self as coord, AdmissionTier};
use crate::scheduler::{
    self, admit, demand_cores_confident, reserve_top_up, AllocationFrame, SchedulerConfig,
};
use crate::simulator::{Cluster, SharedCluster};
use crate::trace::LadderTraceSet;
use crate::tuner::policy::oracle_best;
use crate::tuner::{BudgetedController, RunOutcome, StepOutcome, TunerConfig};
use crate::util::json::Json;
use crate::workloads::{AppProfile, WorkloadConfig};

/// Post-warmup bound-met fraction every app is expected to clear.
pub const FLEET_SLO_FRAC: f64 = 0.80;

/// Cost multiplier of the scripted fleet-wide load shift (applied to the
/// heavy apps' content scripts at `load_shift_frame`).
pub const LOAD_SHIFT_MULT: f64 = 1.9;

/// Cost multiplier of the scripted load *drop* scenario family: heavy
/// apps' costs roughly halve at the shift frame — the regime in which
/// epoch-granular admission re-admits tenants parked under load pressure.
pub const LOAD_DROP_MULT: f64 = crate::workloads::LOAD_DROP_MULT;

/// Allocation policy of the fleet run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FleetMode {
    /// Every app pinned at the even share of the shared cluster — the
    /// baseline the dynamic scheduler is measured against.
    #[default]
    Static,
    /// Marginal-utility water-filling reallocation every epoch.
    Dynamic,
}

impl FleetMode {
    pub fn name(self) -> &'static str {
        match self {
            FleetMode::Static => "static",
            FleetMode::Dynamic => "dynamic",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "static" => Ok(FleetMode::Static),
            "dynamic" => Ok(FleetMode::Dynamic),
            other => anyhow::bail!("unknown fleet mode '{other}' (static|dynamic)"),
        }
    }
}

/// Fleet run configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of generated applications tuned concurrently.
    pub apps: usize,
    /// Frames each controller runs.
    pub frames: usize,
    /// Master seed; app `i` derives everything from `seed + i`.
    pub seed: u64,
    /// Size of each app's trace-based action space.
    pub configs_per_app: usize,
    /// Exploration rate; `None` → the paper's 1/√T rule.
    pub epsilon: Option<f64>,
    pub warmup_frames: usize,
    /// The controller solves against `bound × headroom` (violations are
    /// still scored against the spec bound).
    pub bound_headroom: f64,
    /// Shrinkage count of the controller's per-action empirical cost
    /// blend; 0 runs the paper's pure-model exploit.
    pub empirical_blend_k: f64,
    /// Worker OS threads; 0 → one per available core, capped at `apps`.
    pub threads: usize,
    /// The shared, contended cluster the whole fleet draws from.
    pub cluster: Cluster,
    /// Generation envelope for the workloads.
    pub workload: WorkloadConfig,
    /// Allocation policy (static even shares vs dynamic water-filling).
    pub mode: FleetMode,
    /// Alternate Light/Heavy app profiles instead of Balanced ones.
    pub heterogeneous: bool,
    /// Scripted fleet-wide load shift: heavy apps' costs change by
    /// `load_shift_mult` at this frame (requires `heterogeneous`).
    pub load_shift_frame: Option<usize>,
    /// Multiplier of the scripted shift: [`LOAD_SHIFT_MULT`] (the default)
    /// is the classic load *jump*; [`LOAD_DROP_MULT`] scripts the load
    /// *drop* the epoch-admission acceptance scenario uses.
    pub load_shift_mult: f64,
    /// Scheduler policy (epoch length, fairness floor, ladder shape).
    pub scheduler: SchedulerConfig,
    /// Capture the full event trace into [`FleetReport::timeline`]
    /// (`--trace-out`). Off, instrumentation degrades to the always-on
    /// counters/histograms — one branch per frame on the hot path.
    pub trace_events: bool,
    /// Tenant shards for the admission/water-fill tier. `1` is the
    /// single-pool path; `> 1` partitions tenants contiguously and runs
    /// the hierarchical coordinator ([`crate::scheduler::coordinator`])
    /// over in-process shards. Never changes the report — byte-identity
    /// across shard counts is the determinism bar CI holds.
    pub shards: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            apps: 4,
            frames: 500,
            seed: 7,
            configs_per_app: 24,
            epsilon: None,
            warmup_frames: 20,
            bound_headroom: 0.90,
            empirical_blend_k: 8.0,
            threads: 0,
            cluster: Cluster::default(),
            workload: WorkloadConfig::default(),
            mode: FleetMode::Static,
            heterogeneous: false,
            load_shift_frame: None,
            load_shift_mult: LOAD_SHIFT_MULT,
            scheduler: SchedulerConfig::default(),
            trace_events: false,
            shards: 1,
        }
    }
}

impl FleetConfig {
    /// Profile of fleet member `index` under this config.
    pub fn profile_of(&self, index: usize) -> AppProfile {
        AppProfile::for_fleet_member(self.heterogeneous, index, self.workload.profile)
    }

    /// Exact fairness-floor accounting is in effect when the workload
    /// opted in OR admission control is on — the single rule shared by
    /// bound calibration ([`workload_of`](Self::workload_of)) and the
    /// trace/controller replay in [`run_fleet`], which must always price
    /// budgets identically or the bounds lie.
    pub fn exact_accounting(&self) -> bool {
        self.workload.exact_accounting || self.scheduler.admission_any()
    }

    /// Per-app generation envelope (profile + scripted load shift).
    fn workload_of(&self, index: usize) -> WorkloadConfig {
        let mut w = self.workload.clone();
        w.profile = self.profile_of(index);
        if let Some(frame) = self.load_shift_frame {
            if w.profile == AppProfile::Heavy {
                w.load_shift = Some((frame, self.load_shift_mult));
            }
        }
        w.exact_accounting = self.exact_accounting();
        w
    }
}

/// Outcome of tuning one fleet member.
#[derive(Debug, Clone)]
pub struct AppReport {
    pub index: usize,
    pub name: String,
    pub seed: u64,
    pub profile: &'static str,
    pub stages: usize,
    pub knobs: usize,
    pub branches: usize,
    /// The calibrated latency bound L (ms) violations are scored against.
    pub bound_ms: f64,
    pub avg_fidelity: f64,
    /// Clairvoyant optimum at the even share — the same yardstick in both
    /// modes, so static and dynamic runs are directly comparable.
    pub oracle_fidelity: f64,
    /// avg_fidelity / oracle_fidelity (the paper's 90%-of-optimum axis).
    pub fidelity_vs_oracle: f64,
    pub avg_violation_ms: f64,
    pub max_violation_ms: f64,
    pub violation_rate: f64,
    /// Fraction of post-warmup frames under the bound (the fleet SLO).
    pub post_warmup_bound_met_frac: f64,
    /// Candidate actions whose even-share trace meets the bound on ≥95%
    /// of frames — the robustly feasible room at the static baseline.
    pub robust_feasible_actions: usize,
    /// First frame whose trailing-50 mean fidelity reached 90% of oracle.
    pub convergence_frame: Option<usize>,
    pub explore_frames: usize,
    /// Frame-weighted mean core quota this app held.
    pub avg_cores: f64,
    /// Reallocation epochs this app spent parked by admission control
    /// (zero cores, frames dropped). Equal to the epoch count for a
    /// whole-run-parked tenant; epoch-granular admission produces partial
    /// counts as parking rotates.
    pub parked_epochs: usize,
    /// Reallocation epochs this app ran admitted (full epoch batch
    /// executed). The trace fleet runs whole batches, so this is simply
    /// total epochs minus [`parked_epochs`](Self::parked_epochs); the
    /// live path reports the frontier's decision-cadence analogue.
    pub completed_epochs: usize,
    /// Frames this app actually ran (its controller stepped).
    pub admitted_frames: usize,
    /// Post-warmup frames this app ran — the denominator of
    /// [`post_warmup_bound_met_frac`](Self::post_warmup_bound_met_frac);
    /// 0 means the app never produced a scorable frame and is excluded
    /// from the fleet SLO accounting rather than silently passed/failed.
    pub scored_frames: usize,
    /// Frames dropped instead of run (all of them for a parked app).
    pub dropped_frames: usize,
    /// Per-epoch end-to-end latency histograms (always on; empty epochs
    /// for the spans this app spent parked).
    pub latency: EpochLatencies,
    /// Raw accumulator (kept for fleet-wide merging).
    pub stats: PolicyStats,
}

impl AppReport {
    pub fn to_json(&self) -> Json {
        let conv = match self.convergence_frame {
            Some(f) => Json::from(f),
            None => Json::Null,
        };
        Json::obj()
            .put("index", self.index)
            .put("name", self.name.as_str())
            .put("seed", self.seed)
            .put("profile", self.profile)
            .put("stages", self.stages)
            .put("knobs", self.knobs)
            .put("branches", self.branches)
            .put("bound_ms", self.bound_ms)
            .put("avg_fidelity", self.avg_fidelity)
            .put("oracle_fidelity", self.oracle_fidelity)
            .put("fidelity_vs_oracle", self.fidelity_vs_oracle)
            .put("avg_violation_ms", self.avg_violation_ms)
            .put("max_violation_ms", self.max_violation_ms)
            .put("violation_rate", self.violation_rate)
            .put("post_warmup_bound_met_frac", self.post_warmup_bound_met_frac)
            .put("robust_feasible_actions", self.robust_feasible_actions)
            .put("convergence_frame", conv)
            .put("explore_frames", self.explore_frames)
            .put("avg_cores", self.avg_cores)
            .put("parked_epochs", self.parked_epochs)
            .put("completed_epochs", self.completed_epochs)
            .put("admitted_frames", self.admitted_frames)
            .put("scored_frames", self.scored_frames)
            .put("dropped_frames", self.dropped_frames)
            .put("latency_ms", self.latency.total().summary_json())
            .put("epoch_latency_ms", self.latency.to_json())
    }
}

/// Aggregated fleet outcome.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub apps: Vec<AppReport>,
    pub frames: usize,
    pub seed: u64,
    pub mode: FleetMode,
    pub epsilon: f64,
    pub warmup_frames: usize,
    pub bound_headroom: f64,
    /// Even share of the shared cluster (the static baseline quota).
    pub cores_per_app: usize,
    pub total_cores: usize,
    pub fairness_floor: usize,
    /// The shared core ladder (ascending budgets).
    pub levels: Vec<usize>,
    /// One entry per reallocation epoch.
    pub allocations: Vec<AllocationFrame>,
    pub avg_fidelity_vs_oracle: f64,
    pub min_bound_met_frac: f64,
    pub apps_meeting_slo: usize,
    /// Apps that produced at least one scorable (post-warmup, admitted)
    /// frame — the denominator of the fleet SLO.
    pub scored_apps: usize,
    /// Apps parked for the whole run by admission control (they never ran
    /// a frame). Epoch-granular partial parking shows up in
    /// [`parked_app_epochs`](Self::parked_app_epochs) instead.
    pub parked_apps: usize,
    /// Σ over apps of the epochs each spent parked.
    pub parked_app_epochs: usize,
    /// Park/unpark transitions the shared cluster installed — 0 under
    /// whole-run admission, positive when epoch-granular admission
    /// rotates parking or re-admits tenants mid-run.
    pub park_transitions: usize,
    /// Σ over epochs of |cores − previous epoch's cores| — the
    /// reallocation churn the v2 hysteresis exists to cut.
    pub core_churn: usize,
    /// Σ over epochs of the number of apps whose quota moved.
    pub realloc_moves: usize,
    pub merged: PolicyStats,
    /// Full event trace; `Some` only under [`FleetConfig::trace_events`].
    /// Saved as its own artifact (`--trace-out`), never inlined into the
    /// report JSON.
    pub timeline: Option<obs::Timeline>,
}

impl FleetReport {
    /// Every app with scorable frames clears the SLO, judged over the
    /// post-warmup frames it actually ran. Whole-run-parked tenants (an
    /// explicit, separately-reported admission decision) have no scorable
    /// frames and are excluded; a tenant parked for 2 of 100 epochs is
    /// judged on the 98 it ran instead of being silently excluded the way
    /// the old `len - parked_apps` arithmetic did.
    pub fn all_apps_meet_slo(&self) -> bool {
        self.apps_meeting_slo == self.scored_apps
    }

    pub fn to_json(&self) -> Json {
        let details: Vec<Json> = self.apps.iter().map(|a| a.to_json()).collect();
        let allocs: Vec<Json> = self.allocations.iter().map(|a| a.to_json()).collect();
        Json::obj()
            .put("apps", self.apps.len())
            .put("frames", self.frames)
            .put("seed", self.seed)
            .put("mode", self.mode.name())
            .put("epsilon", self.epsilon)
            .put("warmup_frames", self.warmup_frames)
            .put("bound_headroom", self.bound_headroom)
            .put("cores_per_app", self.cores_per_app)
            .put("total_cores", self.total_cores)
            .put("fairness_floor", self.fairness_floor)
            .put(
                "levels",
                Json::Arr(self.levels.iter().map(|&l| Json::from(l)).collect()),
            )
            .put(
                "aggregate",
                Json::obj()
                    .put("avg_fidelity_vs_oracle", self.avg_fidelity_vs_oracle)
                    .put("min_post_warmup_bound_met_frac", self.min_bound_met_frac)
                    .put("slo_frac", FLEET_SLO_FRAC)
                    .put("apps_meeting_slo", self.apps_meeting_slo)
                    .put("scored_apps", self.scored_apps)
                    .put("all_apps_meet_slo", self.all_apps_meet_slo())
                    .put("parked_apps", self.parked_apps)
                    .put("parked_app_epochs", self.parked_app_epochs)
                    .put("park_transitions", self.park_transitions)
                    .put("core_churn", self.core_churn)
                    .put("realloc_moves", self.realloc_moves)
                    .put("avg_violation_ms", self.merged.avg_violation_ms())
                    .put("max_violation_ms", self.merged.max_violation_ms())
                    .put("violation_rate", self.merged.violation_rate()),
            )
            .put("allocations", Json::Arr(allocs))
            .put("apps_detail", Json::Arr(details))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing fleet report {}", path.display()))?;
        Ok(())
    }
}

/// Each app's even slice of the shared cluster: exactly
/// `total_cores / apps` cores (expressed as one virtual server), floored
/// at one physical server's worth. Historical PR-1 helper — the
/// scheduler fleet computes its even share as a plain `total / apps`
/// (no per-server floor; every tenant needs a real quota) and calibrates
/// bounds on that; this remains for external callers and its tests.
pub fn cluster_slice(total: &Cluster, apps: usize) -> Cluster {
    let per_app_cores = (total.total_cores() / apps.max(1)).max(total.cores_per_server);
    Cluster {
        servers: 1,
        cores_per_server: per_app_cores,
        comm_ms_per_frame: total.comm_ms_per_frame,
    }
}

/// Epoch command sent to a pinned worker.
enum Cmd {
    /// Run frames `lo..hi` with the given per-app rung assignment;
    /// `admitted[i] == false` drops the epoch's frames for app `i`
    /// (the warm controller survives for later re-admission).
    Epoch { lo: usize, hi: usize, rungs: Vec<usize>, admitted: Vec<bool> },
    Finish,
}

/// One app's end-of-epoch message back to the scheduler.
struct EpochResult {
    app: usize,
    /// Utility curve over the rung ladder (empty in static mode).
    curve: Vec<f64>,
    /// Per-rung observation counts (the demand-confidence evidence;
    /// empty in static mode).
    obs: Vec<u64>,
}

/// Run the whole fleet: N tuner threads against the shared scheduler.
pub fn run_fleet(cfg: &FleetConfig) -> FleetReport {
    assert!(cfg.apps > 0, "fleet needs at least one app");
    assert!(cfg.frames > 0, "fleet needs at least one frame");
    assert!(
        cfg.warmup_frames < cfg.frames,
        "warmup ({}) must leave post-warmup frames to score the SLO on ({})",
        cfg.warmup_frames,
        cfg.frames
    );
    let total = cfg.cluster.total_cores();
    assert!(
        cfg.scheduler.admission_any() || cfg.apps <= total,
        "fleet of {} apps cannot share {total} cores (one core per app minimum; \
         enable admission control to park the overflow)",
        cfg.apps
    );
    let epoch_mode = cfg.scheduler.admission_epoch;
    assert!(
        !epoch_mode || cfg.mode == FleetMode::Dynamic,
        "epoch-granular admission consumes utility curves; run --mode dynamic"
    );
    let weights0 = cfg.scheduler.weights_at(cfg.apps, 0);
    // admission: under the run-level (v1) flavor, when the requested floor
    // times the fleet size exceeds the pool the lowest-priority apps are
    // parked for the whole run (zero cores, frames dropped) instead of
    // silently over-granting; the epoch-granular flavor makes the same
    // first call through EpochAdmission (floor reservations reproduce the
    // v1 capacity) and then re-decides every epoch from learned demands
    let floor_req = cfg.scheduler.requested_floor(total, cfg.apps);
    let mut adm_state = AdmissionTier::new(
        cfg.apps,
        cfg.shards,
        cfg.scheduler.starvation_bound_or_default(),
        cfg.scheduler.admission_hysteresis,
    );
    let admitted0: Vec<bool> = if epoch_mode {
        adm_state.decide(
            total,
            &weights0,
            &vec![floor_req.clamp(1, total.max(1)); cfg.apps],
        )
    } else if cfg.scheduler.admission {
        admit(total, floor_req, &weights0)
    } else {
        vec![true; cfg.apps]
    };
    let active0: Vec<usize> = (0..cfg.apps).filter(|&i| admitted0[i]).collect();
    let exact = cfg.exact_accounting();
    // bounds are calibrated at the even share of the *initial* co-resident
    // capacity in both flavors, so whole-run and epoch-granular runs of
    // the same scenario stay apples-to-apples
    let even = (total / active0.len()).max(1);
    // epoch admission packs tenants below the requested floor (demand
    // reservations replace the floor guarantee), so its ladder grows
    // sub-floor rungs down to one core
    let ladder_floor = if epoch_mode { 1 } else { floor_req.min(even).max(1) };
    let levels = scheduler::core_levels(
        total,
        active0.len(),
        ladder_floor,
        cfg.scheduler.ladder_rungs,
        cfg.scheduler.max_boost,
    );
    let even_rung = levels
        .iter()
        .position(|&l| l == even)
        // detlint: allow(unwrap) — core_levels inserts the even share unconditionally
        .expect("core_levels always contains the even share");
    let epoch_frames = cfg.scheduler.epoch_frames.max(1);
    let epochs = (cfg.frames + epoch_frames - 1) / epoch_frames;
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.threads
    }
    .clamp(1, cfg.apps);
    let eps = cfg
        .epsilon
        .unwrap_or_else(|| TunerConfig::epsilon_for_horizon(cfg.frames.max(1)));

    let (res_tx, res_rx) = channel::<EpochResult>();
    let (rep_tx, rep_rx) = channel::<AppReport>();
    let mut allocations: Vec<AllocationFrame> = Vec::with_capacity(epochs);
    let mut shared = SharedCluster::parked_even(cfg.cluster.clone(), &admitted0);
    let trace = TraceCollector::new(cfg.trace_events);

    std::thread::scope(|scope| {
        let mut cmd_txs = Vec::with_capacity(threads);
        for w in 0..threads {
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            cmd_txs.push(cmd_tx);
            let res_tx = res_tx.clone();
            let rep_tx = rep_tx.clone();
            let levels = &levels;
            let admitted0 = &admitted0;
            let mut sink = trace.sink();
            scope.spawn(move || {
                // ---- per-worker construction: apps pinned by index ------
                let my: Vec<usize> = (w..cfg.apps).step_by(threads).collect();
                // static mode only ever replays the floor rung (rewards)
                // and the even rung (steps + oracle) — skip simulating
                // the rest of the ladder; each rung is generated from its
                // own per-config seed, so trimming unused rungs leaves
                // the generated traces (and the report) byte-identical
                let gen_levels: Vec<usize> = match cfg.mode {
                    FleetMode::Dynamic => levels.clone(),
                    FleetMode::Static => {
                        let mut v = vec![levels[0]];
                        if even > levels[0] {
                            v.push(even);
                        }
                        v
                    }
                };
                let local_even_rung = gen_levels
                    .iter()
                    .position(|&l| l == even)
                    // detlint: allow(unwrap) — core_levels inserts the even share unconditionally
                    .expect("even share is always a generated rung");
                let mut apps_v = Vec::with_capacity(my.len());
                let mut ladders: Vec<Option<LadderTraceSet>> = Vec::with_capacity(my.len());
                for &i in &my {
                    let app_seed = cfg.seed.wrapping_add(i as u64);
                    let wcfg = cfg.workload_of(i);
                    // bounds calibrated at the even share: the static
                    // baseline must be achievable for every tenant
                    let slice = Cluster {
                        servers: 1,
                        cores_per_server: even,
                        comm_ms_per_frame: cfg.cluster.comm_ms_per_frame,
                    };
                    let app = crate::workloads::generate_on(app_seed, &wcfg, &slice);
                    // whole-run-parked apps never replay a frame: skip the
                    // (costly) ladder tracing, keep the app for its report
                    // row. Epoch-granular admission has no such fast path:
                    // every tenant may run, and a re-admitted tenant must
                    // resume with its warm model and traces.
                    let ladder = (admitted0[i] || epoch_mode).then(|| {
                        LadderTraceSet::generate_with(
                            &app,
                            &cfg.cluster,
                            &gen_levels,
                            cfg.configs_per_app,
                            cfg.frames.max(100),
                            app_seed ^ 0x7A3E_5EED,
                            exact,
                        )
                    });
                    apps_v.push(app);
                    ladders.push(ladder);
                }
                let mut ctls: Vec<Option<BudgetedController<'_>>> = my
                    .iter()
                    .enumerate()
                    .map(|(slot, &i)| {
                        let ladder = ladders[slot].as_ref()?;
                        let app_seed = cfg.seed.wrapping_add(i as u64);
                        let bound = apps_v[slot].spec.latency_bounds_ms[0];
                        let tuner_cfg = TunerConfig {
                            epsilon: eps,
                            bound_ms: bound * cfg.bound_headroom,
                            warmup_frames: cfg.warmup_frames,
                        };
                        let backend = NativeBackend::structured(&apps_v[slot].spec);
                        let mut ctl = BudgetedController::new(
                            &apps_v[slot],
                            ladder,
                            Box::new(backend),
                            tuner_cfg,
                            app_seed ^ 0x00C0_FFEE,
                        )
                        .with_empirical_blend(cfg.empirical_blend_k)
                        .with_time_multiplex(exact);
                        ctl.set_level(local_even_rung);
                        Some(ctl)
                    })
                    .collect();
                let mut steps: Vec<Vec<StepOutcome>> =
                    my.iter().map(|_| Vec::with_capacity(cfg.frames)).collect();
                let mut lat: Vec<EpochLatencies> =
                    my.iter().map(|_| EpochLatencies::with_epochs(epochs)).collect();
                let mut core_frames: Vec<usize> = vec![0; my.len()];
                let mut parked_epochs: Vec<usize> = vec![0; my.len()];
                let mut dropped: Vec<usize> = vec![0; my.len()];
                let mut epochs_seen = 0usize;

                // ---- epoch loop ----------------------------------------
                while let Ok(cmd) = cmd_rx.recv() {
                    match cmd {
                        Cmd::Epoch { lo, hi, rungs, admitted } => {
                            epochs_seen += 1;
                            for (slot, &i) in my.iter().enumerate() {
                                // parked apps drop the epoch's frames on
                                // the floor: nothing runs, nothing is
                                // learned, nothing is reported back —
                                // but (epoch mode) the warm controller
                                // and ladder survive for re-admission
                                if !admitted[i] || ctls[slot].is_none() {
                                    parked_epochs[slot] += 1;
                                    dropped[slot] += hi - lo;
                                    continue;
                                }
                                // detlint: allow(unwrap) — controllers are built for every admitted slot in the loop above
                                let ctl = ctls[slot].as_mut().expect("admitted app");
                                // rungs index the full ladder; static
                                // workers hold a trimmed one and always
                                // sit on the even share
                                let rung = match cfg.mode {
                                    FleetMode::Dynamic => rungs[i],
                                    FleetMode::Static => local_even_rung,
                                };
                                ctl.set_level(rung);
                                core_frames[slot] += ctl.cores() * (hi - lo);
                                let ep = lo / epoch_frames;
                                for f in lo..hi {
                                    let s = ctl.step(f);
                                    lat[slot].record(ep, s.latency_ms);
                                    sink.record_with(|| Event {
                                        tenant: Some(i),
                                        epoch: ep,
                                        frame: Some(f),
                                        seq: 0,
                                        kind: EventKind::Frame {
                                            ms: s.latency_ms,
                                            stage_ms: Vec::new(),
                                            fidelity: s.reward,
                                        },
                                    });
                                    steps[slot].push(s);
                                }
                                let (curve, obs) = match cfg.mode {
                                    FleetMode::Dynamic => {
                                        (ctl.utility_curve(), ctl.rung_observations())
                                    }
                                    FleetMode::Static => (Vec::new(), Vec::new()),
                                };
                                if res_tx.send(EpochResult { app: i, curve, obs }).is_err() {
                                    return;
                                }
                            }
                        }
                        Cmd::Finish => break,
                    }
                }

                // ---- final per-app reports -----------------------------
                for (slot, &i) in my.iter().enumerate() {
                    let app = &apps_v[slot];
                    let bound = app.spec.latency_bounds_ms[0];
                    // identity row + parked-tenant metrics (every frame
                    // dropped, nothing learned); the admitted branch
                    // overrides the metric fields below
                    let base = AppReport {
                        index: i,
                        name: app.spec.name.clone(),
                        seed: cfg.seed.wrapping_add(i as u64),
                        profile: cfg.profile_of(i).name(),
                        stages: app.spec.stages.len(),
                        knobs: app.spec.num_vars(),
                        branches: app.spec.branches().len(),
                        bound_ms: bound,
                        avg_fidelity: 0.0,
                        oracle_fidelity: 0.0,
                        fidelity_vs_oracle: 0.0,
                        avg_violation_ms: 0.0,
                        max_violation_ms: 0.0,
                        violation_rate: 0.0,
                        post_warmup_bound_met_frac: 0.0,
                        robust_feasible_actions: 0,
                        convergence_frame: None,
                        explore_frames: 0,
                        avg_cores: 0.0,
                        parked_epochs: parked_epochs[slot],
                        completed_epochs: epochs_seen - parked_epochs[slot],
                        admitted_frames: 0,
                        scored_frames: 0,
                        dropped_frames: dropped[slot],
                        latency: std::mem::take(&mut lat[slot]),
                        stats: PolicyStats::new(),
                    };
                    let report = match &ladders[slot] {
                        None => base,
                        Some(_) if steps[slot].is_empty() => base,
                        Some(ladder) => {
                            let app_steps = std::mem::take(&mut steps[slot]);
                            let admitted_frames = app_steps.len();
                            let scored = app_steps
                                .iter()
                                .filter(|s| s.frame >= cfg.warmup_frames)
                                .count();
                            let explore_frames =
                                app_steps.iter().filter(|s| s.explored).count();
                            let mut stats = PolicyStats::new();
                            for s in &app_steps {
                                stats.observe(s.reward, s.latency_ms, bound);
                            }
                            let even_ts = ladder.set(local_even_rung);
                            let oracle = oracle_best(even_ts, cfg.frames, bound);
                            let oracle_fid = oracle.avg_reward.max(1e-9);
                            let outcome = RunOutcome {
                                avg_reward: stats.avg_reward(),
                                avg_violation_ms: stats.avg_violation_ms(),
                                max_violation_ms: stats.max_violation_ms(),
                                violation_rate: stats.violation_rate(),
                                explore_frames,
                                steps: app_steps,
                            };
                            // dropped frames earn zero fidelity: parking
                            // is charged to the tenant's average, never
                            // hidden (full runs keep the historical value)
                            let avg_fid = if admitted_frames == cfg.frames {
                                outcome.avg_reward
                            } else {
                                outcome.steps.iter().map(|s| s.reward).sum::<f64>()
                                    / cfg.frames as f64
                            };
                            let met = if scored == 0 {
                                0.0
                            } else {
                                outcome.bound_met_frac_after(cfg.warmup_frames, bound)
                            };
                            AppReport {
                                avg_fidelity: avg_fid,
                                oracle_fidelity: oracle.avg_reward,
                                fidelity_vs_oracle: avg_fid / oracle_fid,
                                avg_violation_ms: outcome.avg_violation_ms,
                                max_violation_ms: outcome.max_violation_ms,
                                violation_rate: outcome.violation_rate,
                                post_warmup_bound_met_frac: met,
                                robust_feasible_actions: even_ts
                                    .traces
                                    .iter()
                                    .filter(|t| t.frac_under(bound) >= 0.95)
                                    .count(),
                                convergence_frame: outcome
                                    .convergence_frame(50, 0.9 * oracle.avg_reward),
                                explore_frames,
                                avg_cores: core_frames[slot] as f64 / cfg.frames as f64,
                                admitted_frames,
                                scored_frames: scored,
                                stats,
                                ..base
                            }
                        }
                    };
                    if rep_tx.send(report).is_err() {
                        return;
                    }
                }
            });
        }
        drop(res_tx);
        drop(rep_tx);

        // ---- scheduler main loop ---------------------------------------
        let mut curves: Vec<Vec<f64>> = vec![Vec::new(); cfg.apps];
        let mut rung_obs: Vec<Vec<u64>> = vec![Vec::new(); cfg.apps];
        // incumbent rungs for the hysteresis term (active apps only)
        let mut prev_rungs: Vec<usize> = vec![even_rung; cfg.apps];
        let mut admitted = admitted0.clone();
        // scheduler-side event sink (single-threaded, deterministic);
        // transitions are diffed against the nominal all-admitted start
        let mut sched_sink = trace.sink();
        let mut prev_admitted: Vec<bool> = vec![true; cfg.apps];
        for e in 0..epochs {
            let frame0 = e * epoch_frames;
            let w = cfg.scheduler.weights_at(cfg.apps, frame0);
            // per-epoch demand reservations: the cores each tenant's
            // learned curve tops out at, capped at the even share so one
            // hungry tenant cannot reserve three seats (the water-filler
            // still boosts past the cap from what is actually free);
            // curve-less tenants (warmup / never admitted) reserve the
            // requested floor
            let reservations: Vec<usize> = if epoch_mode {
                (0..cfg.apps)
                    .map(|i| {
                        if curves[i].len() == levels.len() {
                            // demand-confidence: rungs without >= N
                            // observations cannot carry the demand, so an
                            // immature model reserves honestly instead of
                            // optimistically under-reserving (N = 0 is
                            // the historical behavior, bit-for-bit)
                            demand_cores_confident(
                                &curves[i],
                                &levels,
                                even,
                                &rung_obs[i],
                                cfg.scheduler.demand_confidence,
                            )
                            .clamp(1, even)
                        } else {
                            floor_req.clamp(1, even)
                        }
                    })
                    .collect()
            } else {
                Vec::new()
            };
            if epoch_mode
                && e > 0
                && e <= cfg.scheduler.warmup_epochs
                && !adm_state.overdue_pending()
            {
                // hold the initial decision through warmup (curves are
                // still forming) but tick the rotation clock — unless a
                // starvation bound tighter than the warmup span is due,
                // in which case rotation must not wait
                admitted = adm_state.hold();
            } else if epoch_mode && e > 0 {
                admitted = adm_state.decide(total, &w, &reservations);
            }
            let active: Vec<usize> = (0..cfg.apps).filter(|&i| admitted[i]).collect();
            let parked: Vec<bool> = admitted.iter().map(|&a| !a).collect();
            let dynamic_ready = cfg.mode == FleetMode::Dynamic
                && e >= cfg.scheduler.warmup_epochs
                && (epoch_mode
                    || active.iter().all(|&i| curves[i].len() == levels.len()));
            let rungs: Vec<usize> = if dynamic_ready {
                // solve over the admitted subset; parked apps hold no
                // rung (their quota is forced to zero below). A freshly
                // re-admitted tenant with no curve yet enters flat-zero
                // (the reservation top-up below is what seats it).
                let sub_curves: Vec<Vec<f64>> = active
                    .iter()
                    .map(|&i| {
                        if curves[i].len() == levels.len() {
                            curves[i].clone()
                        } else {
                            vec![0.0; levels.len()]
                        }
                    })
                    .collect();
                let sub_w: Vec<f64> = active.iter().map(|&i| w[i]).collect();
                let sub_prev: Vec<usize> =
                    active.iter().map(|&i| prev_rungs[i]).collect();
                // 2% fairness holdback (epoch mode only): water-fill
                // over `total - hold` so the reservation top-up below
                // has idle cores to seat under-served tenants with —
                // at the full pool it is provably a no-op (the phase-2
                // even-share raise strictly dominates it). Floor-guarded
                // so tight pools still seat every admitted floor rung.
                // Mirror-validated: python/tests/test_shard_mirror.py.
                let fill_budget = if epoch_mode {
                    let hold =
                        (total / 50).min(total.saturating_sub(active.len() * levels[0]));
                    total - hold
                } else {
                    total
                };
                let sub = if cfg.shards > 1 {
                    coord::allocate_v2_sharded(
                        cfg.shards,
                        &sub_curves,
                        &levels,
                        fill_budget,
                        &sub_w,
                        Some(&sub_prev),
                        cfg.scheduler.hysteresis,
                    )
                } else {
                    scheduler::allocate_v2(
                        &sub_curves,
                        &levels,
                        fill_budget,
                        &sub_w,
                        Some(&sub_prev),
                        cfg.scheduler.hysteresis,
                    )
                };
                let mut full = vec![0usize; cfg.apps];
                for (k, &i) in active.iter().enumerate() {
                    full[i] = sub[k];
                }
                if epoch_mode {
                    // raise admitted tenants from idle cores toward their
                    // reservations (priority order): a starved model must
                    // not be left at the sub-floor scraps the packed
                    // ladder would otherwise hand it
                    reserve_top_up(
                        &mut full,
                        &levels,
                        total,
                        &admitted,
                        &reservations,
                        even,
                        &w,
                    );
                }
                full
            } else {
                // warmup (and static mode): pin the even share; epoch
                // admission may be co-residing more tenants than the
                // initial capacity, so its pin is the budget-safe share
                let fb = if epoch_mode {
                    let share = (total / active.len().max(1)).max(1);
                    levels.iter().rposition(|&l| l <= share).unwrap_or(0)
                } else {
                    even_rung
                };
                let mut full = vec![0usize; cfg.apps];
                for &i in &active {
                    full[i] = fb;
                }
                full
            };
            for &i in &active {
                prev_rungs[i] = rungs[i];
            }
            let cores: Vec<usize> = (0..cfg.apps)
                .map(|a| if admitted[a] { levels[rungs[a]] } else { 0 })
                .collect();
            // the shared cluster enforces the budget + floor invariants;
            // the report quotes the quotas it actually installed
            shared.set_quotas_parked(&cores, &parked);
            let predicted_utility: Vec<f64> = rungs
                .iter()
                .enumerate()
                .map(|(a, &r)| {
                    if admitted[a] {
                        curves[a].get(r).copied().unwrap_or(0.0)
                    } else {
                        0.0
                    }
                })
                .collect();
            let churn_cores = allocations
                .last()
                .map(|prev| AllocationFrame::churn_vs(shared.quotas(), prev))
                .unwrap_or(0);
            allocations.push(AllocationFrame {
                epoch: e,
                start_frame: e * epoch_frames,
                levels: rungs.clone(),
                cores: shared.quotas().to_vec(),
                predicted_utility,
                parked: parked.clone(),
                churn_cores,
            });
            for i in 0..cfg.apps {
                if admitted[i] != prev_admitted[i] {
                    sched_sink.record_with(|| Event {
                        tenant: Some(i),
                        epoch: e,
                        frame: None,
                        seq: 0,
                        kind: if admitted[i] {
                            EventKind::Resume { at_epoch: e }
                        } else {
                            EventKind::Park
                        },
                    });
                }
            }
            prev_admitted.copy_from_slice(&admitted);
            sched_sink.record_with(|| Event {
                tenant: None,
                epoch: e,
                frame: None,
                seq: 0,
                kind: EventKind::Admission {
                    admitted: admitted.clone(),
                    reservations: reservations.clone(),
                },
            });
            sched_sink.record_with(|| Event {
                tenant: None,
                epoch: e,
                frame: None,
                seq: 0,
                kind: EventKind::Alloc {
                    cores: shared.quotas().to_vec(),
                    parked: parked.clone(),
                    churn_cores,
                },
            });
            if cfg.shards > 1 {
                // Shard-stamped allocation slices: one event per shard
                // (seq = shard id keeps the per-epoch event key unique)
                // so a timeline reader can attribute quota movement to
                // the owning shard without re-deriving the partition.
                for (sid, &(lo_t, hi_t)) in
                    coord::shard_bounds(cfg.apps, cfg.shards).iter().enumerate()
                {
                    sched_sink.record_with(|| Event {
                        tenant: None,
                        epoch: e,
                        frame: None,
                        seq: sid,
                        kind: EventKind::ShardAlloc {
                            shard: sid,
                            lo: lo_t,
                            hi: hi_t,
                            cores: shared.quotas()[lo_t..hi_t].to_vec(),
                        },
                    });
                }
            }
            let lo = e * epoch_frames;
            let hi = (lo + epoch_frames).min(cfg.frames);
            for tx in &cmd_txs {
                tx.send(Cmd::Epoch {
                    lo,
                    hi,
                    rungs: rungs.clone(),
                    admitted: admitted.clone(),
                })
                // detlint: allow(unwrap) — a dead fleet worker must take the run down, not silently drop tenants
                .expect("worker alive");
            }
            for _ in 0..active.len() {
                // bounded wait: a panicking worker drops only its own
                // sender (its siblings keep theirs), so a plain recv()
                // would hang forever masking the original panic — time
                // out far above any epoch length and fail loudly instead
                let r = res_rx
                    .recv_timeout(std::time::Duration::from_secs(300))
                    // detlint: allow(unwrap) — a dead fleet worker must take the run down, not silently drop tenants
                    .expect("a fleet worker died mid-epoch (see its panic above)");
                curves[r.app] = r.curve;
                rung_obs[r.app] = r.obs;
            }
        }
        for tx in &cmd_txs {
            tx.send(Cmd::Finish).expect("worker alive");
        }
    });

    // every sink (workers + scheduler) is dropped by now; drain cannot block
    let timeline = cfg.trace_events.then(|| obs::Timeline {
        source: "fleet".to_string(),
        seed: cfg.seed,
        apps: cfg.apps,
        frames: cfg.frames,
        epoch_frames,
        events: trace.drain(),
    });

    let mut apps: Vec<AppReport> = rep_rx.iter().collect();
    assert_eq!(apps.len(), cfg.apps, "every fleet member must report");
    apps.sort_by_key(|r| r.index);

    let n = apps.len() as f64;
    // parked frames count as zero fidelity — parking is not free, the
    // aggregate owns it — but the SLO floor is over scorable frames only
    // (a parked tenant is an explicit admission decision, not a miss)
    let avg_ratio = apps.iter().map(|a| a.fidelity_vs_oracle).sum::<f64>() / n;
    let min_met = apps
        .iter()
        .filter(|a| a.scored_frames > 0)
        .map(|a| a.post_warmup_bound_met_frac)
        .fold(f64::INFINITY, f64::min);
    let scored_apps = apps.iter().filter(|a| a.scored_frames > 0).count();
    let meeting = apps
        .iter()
        .filter(|a| a.scored_frames > 0 && a.post_warmup_bound_met_frac >= FLEET_SLO_FRAC)
        .count();
    let mut merged = PolicyStats::new();
    for a in &apps {
        merged.merge(&a.stats);
    }
    let core_churn = allocations.iter().map(|a| a.churn_cores).sum();
    let realloc_moves = allocations
        .windows(2)
        .map(|w| w[1].moved_apps(&w[0]))
        .sum();
    FleetReport {
        frames: cfg.frames,
        seed: cfg.seed,
        mode: cfg.mode,
        epsilon: eps,
        warmup_frames: cfg.warmup_frames,
        bound_headroom: cfg.bound_headroom,
        cores_per_app: even,
        total_cores: total,
        fairness_floor: ladder_floor,
        levels,
        allocations,
        avg_fidelity_vs_oracle: avg_ratio,
        min_bound_met_frac: min_met,
        apps_meeting_slo: meeting,
        scored_apps,
        parked_apps: apps.iter().filter(|a| a.admitted_frames == 0).count(),
        parked_app_epochs: apps.iter().map(|a| a.parked_epochs).sum(),
        park_transitions: shared.park_transitions(),
        core_churn,
        realloc_moves,
        merged,
        timeline,
        apps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FleetConfig {
        FleetConfig {
            apps: 3,
            frames: 120,
            seed: 42,
            configs_per_app: 10,
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn cluster_slice_splits_evenly() {
        let total = Cluster::default(); // 15 x 8 = 120 cores
        assert_eq!(cluster_slice(&total, 8).total_cores(), 15);
        assert_eq!(cluster_slice(&total, 1).total_cores(), 120);
        // the slice never oversubscribes the shared budget ...
        for apps in 1..=15 {
            assert!(cluster_slice(&total, apps).total_cores() * apps <= 120, "{apps}");
        }
        // ... until fleets exceed the server count, which co-tenant at
        // one server's worth each
        assert_eq!(cluster_slice(&total, 1000).total_cores(), 8);
    }

    #[test]
    #[should_panic(expected = "warmup")]
    fn warmup_exceeding_frames_is_rejected() {
        let mut cfg = small_cfg();
        cfg.warmup_frames = cfg.frames;
        run_fleet(&cfg);
    }

    #[test]
    fn fleet_runs_every_app() {
        let report = run_fleet(&small_cfg());
        assert_eq!(report.apps.len(), 3);
        for (i, a) in report.apps.iter().enumerate() {
            assert_eq!(a.index, i);
            assert_eq!(a.seed, 42 + i as u64);
            assert_eq!(a.profile, "balanced");
            assert!(a.bound_ms > 0.0);
            assert!((0.0..=1.0).contains(&a.post_warmup_bound_met_frac));
            assert!((0.0..=1.0).contains(&a.violation_rate));
            assert!(a.avg_fidelity > 0.0, "app {i} learned nothing");
            // static mode: every app held the even share throughout
            assert_eq!(a.avg_cores, report.cores_per_app as f64, "app {i}");
        }
        assert!(report.avg_fidelity_vs_oracle > 0.0);
        assert!(report.min_bound_met_frac <= 1.0);
        // one allocation record per epoch, all at the even share
        assert_eq!(report.allocations.len(), 3); // 120 frames / 50-frame epochs
        for alloc in &report.allocations {
            assert_eq!(alloc.cores, vec![report.cores_per_app; 3]);
            assert!(alloc.total_cores() <= report.total_cores);
        }
    }

    #[test]
    fn report_json_shape() {
        let report = run_fleet(&small_cfg());
        let j = report.to_json();
        assert_eq!(j.req("apps").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("mode").unwrap().as_str().unwrap(), "static");
        let agg = j.req("aggregate").unwrap();
        assert!(agg.req("min_post_warmup_bound_met_frac").unwrap().as_f64().is_ok());
        let details = j.req("apps_detail").unwrap().as_arr().unwrap();
        assert_eq!(details.len(), 3);
        assert_eq!(details[1].req("index").unwrap().as_usize().unwrap(), 1);
        let allocs = j.req("allocations").unwrap().as_arr().unwrap();
        assert_eq!(allocs.len(), report.allocations.len());
        // round-trips through the in-tree parser
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req("seed").unwrap().as_u64().unwrap(), 42);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut a_cfg = small_cfg();
        a_cfg.threads = 1;
        let mut b_cfg = small_cfg();
        b_cfg.threads = 3;
        let a = run_fleet(&a_cfg);
        let b = run_fleet(&b_cfg);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn shard_count_does_not_change_results() {
        // Sharding is topology, not semantics: the coordinator's token
        // protocol reproduces single-pool admission and water-filling
        // bit-for-bit (mirror-validated in
        // python/tests/test_shard_mirror.py), so the whole fleet report
        // matches byte-for-byte. The cluster is sized so admission
        // actually parks and rotates tenants — a vacuous all-admitted
        // run would not exercise the sharded decide at all.
        let mut base = small_cfg();
        base.apps = 6;
        base.frames = 90;
        base.mode = FleetMode::Dynamic;
        base.scheduler.epoch_frames = 15;
        base.scheduler.admission_epoch = true;
        base.scheduler.fairness_floor = 5;
        base.cluster = Cluster {
            servers: 1,
            cores_per_server: 24,
            comm_ms_per_frame: 0.0,
        };
        let want = run_fleet(&base).to_json().to_string();
        for shards in [2usize, 3] {
            let mut cfg = base.clone();
            cfg.shards = shards;
            let got = run_fleet(&cfg).to_json().to_string();
            assert_eq!(got, want, "{shards}-shard fleet drifts from the single pool");
        }
    }

    #[test]
    fn heterogeneous_fleet_alternates_profiles() {
        let cfg = FleetConfig {
            apps: 4,
            frames: 60,
            seed: 9,
            configs_per_app: 6,
            threads: 2,
            heterogeneous: true,
            load_shift_frame: Some(30),
            ..Default::default()
        };
        let report = run_fleet(&cfg);
        let profiles: Vec<&str> = report.apps.iter().map(|a| a.profile).collect();
        assert_eq!(profiles, vec!["light", "heavy", "light", "heavy"]);
    }
}
