//! Measurement-noise model for the simulated testbed.
//!
//! Real stage latencies on the paper's cluster jitter from cache effects,
//! scheduler preemption and external load. We model this as multiplicative
//! log-normal noise (~5% sigma) plus rare load spikes — enough roughness
//! that the online learner sees realistic residuals, without burying the
//! knob signal.

use crate::util::rng::Rng;

/// Default multiplicative jitter sigma.
pub const DEFAULT_SIGMA: f64 = 0.05;
/// Probability of a load spike on any stage execution.
pub const SPIKE_PROB: f64 = 0.01;
/// Latency multiplier during a spike.
pub const SPIKE_FACTOR: f64 = 1.5;

/// Noise generator (deterministic given its seed).
pub struct NoiseModel {
    pub sigma: f64,
    pub spike_prob: f64,
    pub spike_factor: f64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        NoiseModel {
            sigma: DEFAULT_SIGMA,
            spike_prob: SPIKE_PROB,
            spike_factor: SPIKE_FACTOR,
        }
    }
}

impl NoiseModel {
    /// Noise-free model (for deterministic tests).
    pub fn none() -> Self {
        NoiseModel { sigma: 0.0, spike_prob: 0.0, spike_factor: 1.0 }
    }

    /// Apply noise to a base latency.
    pub fn apply(&self, base_ms: f64, rng: &mut Rng) -> f64 {
        let mut t = base_ms;
        if self.sigma > 0.0 {
            t *= (self.sigma * rng.normal()).exp();
        }
        if self.spike_prob > 0.0 && rng.f64() < self.spike_prob {
            t *= self.spike_factor;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_is_identity() {
        let mut rng = Rng::new(1);
        let n = NoiseModel::none();
        assert_eq!(n.apply(42.0, &mut rng), 42.0);
    }

    #[test]
    fn noise_is_multiplicative_and_centered() {
        let mut rng = Rng::new(2);
        let n = NoiseModel { spike_prob: 0.0, ..Default::default() };
        let samples: Vec<f64> = (0..20_000).map(|_| n.apply(100.0, &mut rng)).collect();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((mean - 100.0).abs() < 1.0, "mean {mean}");
        assert!(samples.iter().all(|&s| s > 60.0 && s < 160.0));
    }

    #[test]
    fn spikes_occur_at_configured_rate() {
        let mut rng = Rng::new(3);
        let n = NoiseModel { sigma: 0.0, spike_prob: 0.1, spike_factor: 2.0 };
        let spikes = (0..10_000)
            .filter(|_| n.apply(1.0, &mut rng) > 1.5)
            .count();
        assert!((800..1200).contains(&spikes), "{spikes}");
    }

    #[test]
    fn deterministic_given_seed() {
        let n = NoiseModel::default();
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..100 {
            assert_eq!(n.apply(10.0, &mut a), n.apply(10.0, &mut b));
        }
    }
}
