//! Virtual-time cluster simulator — the stand-in for the paper's testbed
//! of 15 servers × 8 cores (Sec. 4.1).
//!
//! The simulator executes one frame of an application at a time: it grants
//! data-parallel worker allocations under the cluster's core budget,
//! evaluates each stage's analytic cost model, applies measurement noise,
//! and returns per-stage latencies plus the end-to-end latency (the
//! weighted critical path through the data-flow graph) and the frame's
//! fidelity. Traces produced this way are what the experiments replay,
//! mirroring the paper's trace-based methodology.

pub mod noise;

pub use noise::NoiseModel;

use crate::apps::App;
use crate::dataflow::critical_path;

/// The paper's cluster: 15 servers, two quad-core Xeon E5440 each.
pub const DEFAULT_SERVERS: usize = 15;
pub const DEFAULT_CORES_PER_SERVER: usize = 8;

/// Cluster description.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub servers: usize,
    pub cores_per_server: usize,
    /// Per-connector communication latency (ms) for a full-resolution
    /// frame over the 1 GbE interconnect; scaled frames cost less. The
    /// paper omits this from its formulation ("processing time ...
    /// dominates other sources, such as network transfer overheads") and
    /// names it as future work — 0.0 (the default) reproduces the paper;
    /// setting it exercises the edge-weighted critical path.
    pub comm_ms_per_frame: f64,
}

impl Default for Cluster {
    fn default() -> Self {
        Cluster {
            servers: DEFAULT_SERVERS,
            cores_per_server: DEFAULT_CORES_PER_SERVER,
            comm_ms_per_frame: 0.0,
        }
    }
}

impl Cluster {
    pub fn total_cores(&self) -> usize {
        self.servers * self.cores_per_server
    }
}

/// Grant worker requests under an explicit core budget: requests are
/// granted as-is when they fit; otherwise every request is scaled back
/// proportionally (floored at one worker). Shared by [`ClusterSim`], the
/// ladder tracer and the scheduler's effective-knob clamping so the three
/// can never disagree about what a budget does to a configuration.
///
/// Known approximation: the one-worker-per-stage floor means a budget
/// below the stage count still grants `num_stages` workers — a pipeline
/// on a quota smaller than its stage count effectively time-shares
/// residual cores. By default that sharing is invisible to the
/// accounting (the historical behavior every pre-v2 trace depends on);
/// exact-accounting mode ([`ClusterSim::set_time_multiplex`], enabled by
/// the scheduler's admission control) charges it back as the
/// [`time_multiplex_factor`] latency multiplier, so a 7-core quota on a
/// 12-stage pipeline runs 12 workers at 12/7 the latency instead of
/// silently over-granting.
pub fn grant_under(requested: &[usize], budget: usize) -> Vec<usize> {
    let total: usize = requested.iter().sum();
    if total <= budget {
        return requested.to_vec();
    }
    let scale = budget as f64 / total as f64;
    requested
        .iter()
        // detlint: allow(lossy-cast) — scaled worker count: floor-then-max(1) is the documented grant rule, exact below 2^53
        .map(|&r| ((r as f64 * scale).floor() as usize).max(1))
        .collect()
}

/// Latency multiplier charged when the one-worker-per-stage floor forces
/// more workers than the budget holds cores: `granted_total / budget`
/// once the grant exceeds the budget, 1 otherwise. The fleet's exact
/// fairness-floor accounting (admission control) multiplies every stage
/// latency by this, modeling the time-multiplexing a too-small quota
/// actually buys.
pub fn time_multiplex_factor(granted_total: usize, budget: usize) -> f64 {
    if granted_total > budget && budget > 0 {
        granted_total as f64 / budget as f64
    } else {
        1.0
    }
}

/// One shared, contended cluster divided into per-app core quotas — the
/// fleet scheduler's view of the testbed. Unlike the PR-1 era per-app
/// slices (independent `Cluster` values that could drift out of sync with
/// the physical budget), a `SharedCluster` owns the single core pool and
/// validates every quota assignment against it.
#[derive(Debug, Clone)]
pub struct SharedCluster {
    pub cluster: Cluster,
    quotas: Vec<usize>,
    /// Park/unpark transitions installed over this cluster's lifetime: the
    /// number of quota assignments that moved an app between zero and
    /// non-zero cores. Whole-run parking (v1 admission) never transitions;
    /// epoch-granular admission does, and the fleet report surfaces the
    /// count so rotation churn is visible.
    park_transitions: usize,
}

impl SharedCluster {
    /// Split `cluster` into `apps` even quotas (the static baseline).
    pub fn even(cluster: Cluster, apps: usize) -> Self {
        assert!(apps > 0, "shared cluster needs at least one tenant");
        assert!(
            apps <= cluster.total_cores(),
            "even split needs at least one core per tenant \
             (admission fleets use parked_even)"
        );
        let q = (cluster.total_cores() / apps).max(1);
        SharedCluster { quotas: vec![q; apps], cluster, park_transitions: 0 }
    }

    /// [`even`](Self::even) over the *admitted* subset of an
    /// admission-controlled fleet: admitted tenants split the pool
    /// evenly, parked tenants hold zero cores — so even the initial
    /// (pre-epoch-0) state satisfies the budget invariant this type
    /// exists to enforce.
    pub fn parked_even(cluster: Cluster, admitted: &[bool]) -> Self {
        let n = admitted.iter().filter(|&&a| a).count();
        assert!(n > 0, "shared cluster needs at least one admitted tenant");
        assert!(n <= cluster.total_cores(), "one core per admitted tenant minimum");
        let q = (cluster.total_cores() / n).max(1);
        let quotas = admitted.iter().map(|&a| if a { q } else { 0 }).collect();
        SharedCluster { quotas, cluster, park_transitions: 0 }
    }

    pub fn apps(&self) -> usize {
        self.quotas.len()
    }

    pub fn quota(&self, app: usize) -> usize {
        self.quotas[app]
    }

    pub fn quotas(&self) -> &[usize] {
        &self.quotas
    }

    /// Park/unpark transitions installed so far (see the field docs).
    pub fn park_transitions(&self) -> usize {
        self.park_transitions
    }

    fn count_transitions(&mut self, quotas: &[usize]) {
        self.park_transitions += self
            .quotas
            .iter()
            .zip(quotas)
            .filter(|(&old, &new)| (old == 0) != (new == 0))
            .count();
    }

    /// Install a new per-app quota vector (one reallocation epoch).
    /// Panics if the vector oversubscribes the shared budget or starves
    /// an app to zero — scheduler bugs must not be silently absorbed.
    pub fn set_quotas(&mut self, quotas: &[usize]) {
        assert_eq!(quotas.len(), self.quotas.len(), "quota vector shape");
        let sum: usize = quotas.iter().sum();
        assert!(
            sum <= self.cluster.total_cores(),
            "quotas {sum} oversubscribe the {}-core cluster",
            self.cluster.total_cores()
        );
        assert!(quotas.iter().all(|&q| q >= 1), "zero-core quota");
        self.count_transitions(quotas);
        self.quotas.copy_from_slice(quotas);
    }

    /// [`set_quotas`](Self::set_quotas) for admission-controlled fleets:
    /// apps marked `parked` hold exactly zero cores (their frames are
    /// dropped, not run), every admitted app still keeps a real quota,
    /// and the shared budget stays inviolate.
    pub fn set_quotas_parked(&mut self, quotas: &[usize], parked: &[bool]) {
        assert_eq!(quotas.len(), self.quotas.len(), "quota vector shape");
        assert_eq!(parked.len(), self.quotas.len(), "parked vector shape");
        let sum: usize = quotas.iter().sum();
        assert!(
            sum <= self.cluster.total_cores(),
            "quotas {sum} oversubscribe the {}-core cluster",
            self.cluster.total_cores()
        );
        for (q, &p) in quotas.iter().zip(parked) {
            if p {
                assert_eq!(*q, 0, "parked app must hold zero cores");
            } else {
                assert!(*q >= 1, "zero-core quota for an admitted app");
            }
        }
        self.count_transitions(quotas);
        self.quotas.copy_from_slice(quotas);
    }
}

/// Result of simulating one frame.
#[derive(Debug, Clone)]
pub struct FrameResult {
    /// Per-stage latencies (ms), indexed like the app graph.
    pub stage_ms: Vec<f64>,
    /// End-to-end latency: weighted critical path (ms).
    pub end_to_end_ms: f64,
    /// Fidelity r(x, k) of the frame's output.
    pub fidelity: f64,
    /// Workers actually granted per stage.
    pub granted_workers: Vec<usize>,
}

/// Always-on observability counters a [`ClusterSim`] accumulates as it
/// runs frames (ISSUE 7): frame count plus the end-to-end latency
/// distribution, cheap enough to never turn off.
#[derive(Debug, Clone, Default)]
pub struct SimCounters {
    /// Frames simulated through [`ClusterSim::run_frame`].
    pub frames: u64,
    /// End-to-end latency histogram across those frames.
    pub latency: crate::obs::Histogram,
}

/// Virtual-time cluster simulator.
pub struct ClusterSim {
    pub cluster: Cluster,
    pub noise: NoiseModel,
    rng: crate::util::Rng,
    /// Per-frame fidelity measurement noise sigma.
    pub fidelity_sigma: f64,
    counters: SimCounters,
    /// Optional per-app core quota on a shared cluster: grants are made
    /// against `min(core_budget, total_cores)` instead of the whole pool.
    /// `None` (the default) reproduces the dedicated-cluster behavior.
    core_budget: Option<usize>,
    /// Exact accounting: charge [`time_multiplex_factor`] on every stage
    /// when the one-worker-per-stage floor over-grants a small budget.
    /// Off by default — the historical traces (and the paper's dedicated
    /// cluster) never hit the regime, and the scheduler only turns it on
    /// together with admission control.
    time_multiplex: bool,
}

impl ClusterSim {
    pub fn new(cluster: Cluster, noise: NoiseModel, seed: u64) -> Self {
        ClusterSim {
            cluster,
            noise,
            rng: crate::util::Rng::new(seed),
            fidelity_sigma: 0.02,
            counters: SimCounters::default(),
            core_budget: None,
            time_multiplex: false,
        }
    }

    /// Always-on counters: frames simulated and their latency histogram.
    pub fn counters(&self) -> &SimCounters {
        &self.counters
    }

    /// Deterministic simulator (no latency or fidelity noise).
    pub fn deterministic(cluster: Cluster) -> Self {
        let mut sim = ClusterSim::new(cluster, NoiseModel::none(), 0);
        sim.fidelity_sigma = 0.0;
        sim
    }

    /// Contended mode: grant against this app's quota of the shared
    /// cluster rather than the full pool (the scheduler re-points this
    /// each reallocation epoch).
    pub fn with_core_budget(mut self, cores: usize) -> Self {
        self.set_core_budget(Some(cores));
        self
    }

    pub fn set_core_budget(&mut self, cores: Option<usize>) {
        if let Some(c) = cores {
            assert!(c >= 1, "core budget must grant at least one core");
        }
        self.core_budget = cores;
    }

    /// Exact accounting mode: see [`time_multiplex_factor`].
    pub fn with_time_multiplex(mut self, on: bool) -> Self {
        self.set_time_multiplex(on);
        self
    }

    pub fn set_time_multiplex(&mut self, on: bool) {
        self.time_multiplex = on;
    }

    /// The budget grants are made against: the app's quota on a shared
    /// cluster, or the whole pool on a dedicated one.
    pub fn effective_budget(&self) -> usize {
        let total = self.cluster.total_cores();
        self.core_budget.map_or(total, |b| b.min(total))
    }

    /// Grant worker allocations under the effective core budget. Requests
    /// are granted as-is when they fit; when the total would exceed the
    /// budget, requests are scaled back proportionally (modeling core
    /// contention when an over-parallelized config lands on the cluster).
    pub fn grant_workers(&self, requested: &[usize]) -> Vec<usize> {
        grant_under(requested, self.effective_budget())
    }

    /// The grant plan for playing `ks`: workers granted per stage under
    /// the effective budget, plus the time-multiplex latency factor those
    /// grants incur (1.0 when exact accounting is off). Pure in the
    /// simulator state — the same `(budget, ks)` always plans the same
    /// grant, which is what lets trace generation hoist the plan out of
    /// the per-frame loop ([`run_frame_cols`](Self::run_frame_cols)).
    pub fn plan_grant(&self, app: &App, ks: &[f64]) -> (Vec<usize>, f64) {
        let requested: Vec<usize> =
            (0..app.graph.len()).map(|s| app.model.requested_workers(s, ks)).collect();
        let granted = self.grant_workers(&requested);
        let tm = if self.time_multiplex {
            time_multiplex_factor(granted.iter().sum(), self.effective_budget())
        } else {
            1.0
        };
        (granted, tm)
    }

    /// Simulate one frame of `app` under raw knob vector `ks`.
    pub fn run_frame(&mut self, app: &App, ks: &[f64], frame: usize) -> FrameResult {
        let (granted, tm) = self.plan_grant(app, ks);
        let mut stage_ms = Vec::with_capacity(app.graph.len());
        let (end_to_end_ms, fidelity) =
            self.run_frame_cols(app, ks, frame, &granted, tm, &mut stage_ms);
        FrameResult { stage_ms, end_to_end_ms, fidelity, granted_workers: granted }
    }

    /// Columnar variant of [`run_frame`](Self::run_frame): per-stage
    /// latencies are **appended** to `stage_out` (the caller's arena
    /// column, e.g. [`FrameBlock`](crate::trace::FrameBlock)) instead of
    /// allocating a fresh vector per frame, and the precomputed grant
    /// plan ([`plan_grant`](Self::plan_grant)) is passed in so trace
    /// generation pays for it once per configuration instead of once per
    /// frame. Returns `(end_to_end_ms, fidelity)`. Draws from the noise
    /// streams in exactly [`run_frame`](Self::run_frame)'s order, so the
    /// two paths produce byte-identical frames.
    pub fn run_frame_cols(
        &mut self,
        app: &App,
        ks: &[f64],
        frame: usize,
        granted: &[usize],
        tm: f64,
        stage_out: &mut Vec<f64>,
    ) -> (f64, f64) {
        let content = app.model.content(frame);
        let start = stage_out.len();
        for s in 0..app.graph.len() {
            // drift is the model's slow per-stage cost walk (1.0 for
            // every drift-free model — exact in IEEE 754, so
            // historical traces stay byte-identical)
            let base = app.model.stage_latency(s, ks, &content, granted[s])
                * app.model.cost_drift(s, frame)
                * tm;
            stage_out.push(self.noise.apply(base, &mut self.rng));
        }
        let stage_ms = &stage_out[start..];
        let end_to_end_ms = if self.cluster.comm_ms_per_frame > 0.0 {
            // communication cost per connector, shrinking with the image
            // scale active on the upstream side (a scaled frame is smaller
            // on the wire); knob 0 is the (first) scale knob in both apps
            let comm = self.cluster.comm_ms_per_frame
                * crate::apps::pixel_fraction(ks[0].max(1.0)).max(0.05);
            crate::dataflow::critical_path::critical_path_with_edges(
                &app.graph,
                stage_ms,
                |_, _| comm,
            )
        } else {
            critical_path(&app.graph, stage_ms)
        };
        let mut fidelity = app.model.fidelity(ks, &content);
        if self.fidelity_sigma > 0.0 {
            fidelity += self.fidelity_sigma * self.rng.normal();
        }
        self.counters.frames += 1;
        self.counters.latency.record(end_to_end_ms);
        (end_to_end_ms, fidelity.clamp(0.0, 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;

    fn pose() -> App {
        app_by_name("pose", find_spec_dir(None).unwrap()).unwrap()
    }

    #[test]
    fn deterministic_frames_repeat() {
        let app = pose();
        let ks = app.spec.defaults();
        let mut a = ClusterSim::deterministic(Cluster::default());
        let mut b = ClusterSim::deterministic(Cluster::default());
        let fa = a.run_frame(&app, &ks, 10);
        let fb = b.run_frame(&app, &ks, 10);
        assert_eq!(fa.stage_ms, fb.stage_ms);
        assert_eq!(fa.fidelity, fb.fidelity);
    }

    #[test]
    fn counters_track_simulated_frames() {
        let app = pose();
        let ks = app.spec.defaults();
        let mut sim = ClusterSim::deterministic(Cluster::default());
        for f in 0..10 {
            sim.run_frame(&app, &ks, f);
        }
        let c = sim.counters();
        assert_eq!(c.frames, 10);
        assert_eq!(c.latency.count(), 10);
        assert!(c.latency.quantile(0.5).unwrap() > 0.0);
    }

    #[test]
    fn end_to_end_is_critical_path() {
        let app = pose();
        let ks = app.spec.defaults();
        let mut sim = ClusterSim::deterministic(Cluster::default());
        let f = sim.run_frame(&app, &ks, 0);
        // pose is a chain: e2e == sum of stages
        let sum: f64 = f.stage_ms.iter().sum();
        assert!((f.end_to_end_ms - sum).abs() < 1e-9);
    }

    #[test]
    fn motion_sift_e2e_below_stage_sum() {
        let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
        let ks = app.spec.defaults();
        let mut sim = ClusterSim::deterministic(Cluster::default());
        let f = sim.run_frame(&app, &ks, 0);
        let sum: f64 = f.stage_ms.iter().sum();
        assert!(f.end_to_end_ms < sum, "parallel branches overlap");
    }

    #[test]
    fn worker_grant_respects_budget() {
        let sim = ClusterSim::deterministic(Cluster {
            servers: 2,
            cores_per_server: 4,
            ..Default::default()
        });
        let granted = sim.grant_workers(&[6, 6, 6]);
        let total: usize = granted.iter().sum();
        assert!(total <= 8 + 2, "proportional floor may round up via max(1): {granted:?}");
        assert!(granted.iter().all(|&g| g >= 1));
    }

    #[test]
    fn core_budget_caps_grants_on_shared_cluster() {
        // a 120-core cluster with a 10-core quota behaves like a 10-core one
        let quota = ClusterSim::deterministic(Cluster::default()).with_core_budget(10);
        let dedicated = ClusterSim::deterministic(Cluster {
            servers: 1,
            cores_per_server: 10,
            ..Default::default()
        });
        for req in [vec![4, 4, 4], vec![1, 1, 1], vec![32, 32]] {
            assert_eq!(quota.grant_workers(&req), dedicated.grant_workers(&req));
        }
        // and the quota never exceeds the physical pool
        let over = ClusterSim::deterministic(Cluster {
            servers: 1,
            cores_per_server: 8,
            ..Default::default()
        })
        .with_core_budget(1000);
        assert_eq!(over.effective_budget(), 8);
    }

    #[test]
    fn quota_changes_latency_of_parallel_configs() {
        let app = pose();
        let ks = [1.0, 1e9, 32.0, 10.0, 10.0]; // heavily parallel request
        let full = ClusterSim::deterministic(Cluster::default())
            .run_frame(&app, &ks, 0)
            .end_to_end_ms;
        let squeezed = ClusterSim::deterministic(Cluster::default())
            .with_core_budget(10)
            .run_frame(&app, &ks, 0)
            .end_to_end_ms;
        assert!(squeezed > full, "10-core quota must slow it: {squeezed} vs {full}");
    }

    #[test]
    fn shared_cluster_quota_invariants() {
        let mut sc = SharedCluster::even(Cluster::default(), 8);
        assert_eq!(sc.apps(), 8);
        assert_eq!(sc.quotas().iter().sum::<usize>(), 120);
        assert!(sc.quotas().iter().all(|&q| q == 15));
        sc.set_quotas(&[7, 7, 7, 7, 7, 31, 45, 7]);
        assert_eq!(sc.quota(6), 45);
    }

    #[test]
    #[should_panic(expected = "oversubscribe")]
    fn oversubscribed_quotas_rejected() {
        let mut sc = SharedCluster::even(Cluster::default(), 4);
        sc.set_quotas(&[40, 40, 40, 40]);
    }

    #[test]
    fn parked_quotas_hold_zero_cores() {
        let mut sc = SharedCluster::even(Cluster::default(), 4);
        sc.set_quotas_parked(&[60, 0, 45, 0], &[false, true, false, true]);
        assert_eq!(sc.quotas(), &[60, 0, 45, 0]);
    }

    #[test]
    fn parked_even_splits_among_admitted_only() {
        // 3 admitted of 5 tenants on 120 cores: 40 each, parked at zero
        let sc = SharedCluster::parked_even(
            Cluster::default(),
            &[true, false, true, false, true],
        );
        assert_eq!(sc.quotas(), &[40, 0, 40, 0, 40]);
        assert!(sc.quotas().iter().sum::<usize>() <= 120);
        // more tenants than cores is fine as long as the admitted fit
        let tiny = Cluster { servers: 1, cores_per_server: 2, comm_ms_per_frame: 0.0 };
        let sc = SharedCluster::parked_even(tiny, &[false, true, false]);
        assert_eq!(sc.quotas(), &[0, 2, 0]);
    }

    #[test]
    fn park_transitions_counted_across_quota_installs() {
        let mut sc =
            SharedCluster::parked_even(Cluster::default(), &[true, true, false]);
        assert_eq!(sc.park_transitions(), 0);
        // unpark app 2, park app 1: two transitions
        sc.set_quotas_parked(&[60, 0, 60], &[false, true, false]);
        assert_eq!(sc.park_transitions(), 2);
        // same shape again: no transition
        sc.set_quotas_parked(&[40, 0, 40], &[false, true, false]);
        assert_eq!(sc.park_transitions(), 2);
        // unpark app 1 (set_quotas counts too)
        sc.set_quotas(&[40, 40, 40]);
        assert_eq!(sc.park_transitions(), 3);
    }

    #[test]
    #[should_panic(expected = "parked app must hold zero cores")]
    fn parked_app_with_cores_rejected() {
        let mut sc = SharedCluster::even(Cluster::default(), 2);
        sc.set_quotas_parked(&[60, 10], &[false, true]);
    }

    #[test]
    #[should_panic(expected = "zero-core quota for an admitted app")]
    fn admitted_app_without_cores_rejected() {
        let mut sc = SharedCluster::even(Cluster::default(), 2);
        sc.set_quotas_parked(&[60, 0], &[false, false]);
    }

    #[test]
    fn time_multiplex_factor_charges_over_grant() {
        assert_eq!(time_multiplex_factor(12, 7), 12.0 / 7.0);
        assert_eq!(time_multiplex_factor(7, 7), 1.0);
        assert_eq!(time_multiplex_factor(3, 7), 1.0);
        assert_eq!(time_multiplex_factor(5, 0), 1.0);
    }

    #[test]
    fn sub_stage_count_quota_charges_latency_multiplier() {
        // the ROADMAP regression: a 7-core quota on a >7-stage pipeline
        // used to run one worker per stage at full speed; with exact
        // accounting the silent over-grant becomes a latency multiplier
        let app = pose(); // 7 stages
        let ks = app.spec.defaults(); // every stage requests 1 worker
        let base = ClusterSim::deterministic(Cluster::default())
            .with_core_budget(4)
            .run_frame(&app, &ks, 0)
            .end_to_end_ms;
        let exact = ClusterSim::deterministic(Cluster::default())
            .with_core_budget(4)
            .with_time_multiplex(true)
            .run_frame(&app, &ks, 0)
            .end_to_end_ms;
        // 7 granted workers on 4 cores -> every stage 7/4 slower
        assert!((exact - base * 7.0 / 4.0).abs() < 1e-9, "{base} -> {exact}");
        // at or above the stage count, exact accounting changes nothing
        let at_floor = ClusterSim::deterministic(Cluster::default())
            .with_core_budget(7)
            .with_time_multiplex(true)
            .run_frame(&app, &ks, 0)
            .end_to_end_ms;
        let plain = ClusterSim::deterministic(Cluster::default())
            .with_core_budget(7)
            .run_frame(&app, &ks, 0)
            .end_to_end_ms;
        assert_eq!(at_floor, plain);
    }

    #[test]
    fn grant_identity_under_budget() {
        let sim = ClusterSim::deterministic(Cluster::default());
        assert_eq!(sim.grant_workers(&[1, 1, 16, 10, 10, 1, 1]), vec![1, 1, 16, 10, 10, 1, 1]);
    }

    #[test]
    fn over_parallelized_config_gets_squeezed() {
        let app = pose();
        // request 96 + 10 + 10 workers on an 8-core toy cluster
        let mut sim = ClusterSim::deterministic(Cluster {
            servers: 1,
            cores_per_server: 8,
            ..Default::default()
        });
        let ks = [1.0, 1e9, 96.0, 10.0, 10.0];
        let f = sim.run_frame(&app, &ks, 0);
        let big = ClusterSim::deterministic(Cluster::default())
            .run_frame(&app, &ks, 0)
            .end_to_end_ms;
        assert!(f.end_to_end_ms > big, "squeezed {} vs full {}", f.end_to_end_ms, big);
    }

    #[test]
    fn noise_perturbs_but_preserves_scale() {
        let app = pose();
        let ks = app.spec.defaults();
        let mut det = ClusterSim::deterministic(Cluster::default());
        let base = det.run_frame(&app, &ks, 0).end_to_end_ms;
        let mut noisy = ClusterSim::new(Cluster::default(), NoiseModel::default(), 5);
        let mut sum = 0.0;
        for _ in 0..200 {
            sum += noisy.run_frame(&app, &ks, 0).end_to_end_ms;
        }
        let mean = sum / 200.0;
        assert!((mean - base).abs() / base < 0.06, "mean {mean} base {base}");
    }

    #[test]
    fn comm_cost_extends_end_to_end() {
        let app = pose();
        let ks = app.spec.defaults();
        let base = ClusterSim::deterministic(Cluster::default())
            .run_frame(&app, &ks, 0)
            .end_to_end_ms;
        let cluster = Cluster { comm_ms_per_frame: 2.0, ..Default::default() };
        let mut sim = ClusterSim::deterministic(cluster);
        let with_comm = sim.run_frame(&app, &ks, 0).end_to_end_ms;
        // pose is a 7-stage chain: 6 connectors x 2 ms at scale 1
        assert!((with_comm - base - 12.0).abs() < 1e-9, "{base} -> {with_comm}");
        // scaling shrinks frames on the wire too
        let ks2 = [4.0, 2.0_f64.powi(31), 1.0, 1.0, 1.0];
        let b2 = ClusterSim::deterministic(Cluster::default())
            .run_frame(&app, &ks2, 0)
            .end_to_end_ms;
        let cluster2 = Cluster { comm_ms_per_frame: 2.0, ..Default::default() };
        let c2 = ClusterSim::deterministic(cluster2).run_frame(&app, &ks2, 0).end_to_end_ms;
        assert!(c2 - b2 < 2.0, "scaled frames must be cheap on the wire: {}", c2 - b2);
    }

    #[test]
    fn fidelity_clamped() {
        let app = pose();
        let ks = app.spec.defaults();
        let mut sim = ClusterSim::new(Cluster::default(), NoiseModel::default(), 6);
        for f in 0..300 {
            let r = sim.run_frame(&app, &ks, f);
            assert!((0.0..=1.0).contains(&r.fidelity));
        }
    }
}
