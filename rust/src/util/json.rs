//! Minimal JSON codec (parser + serializer) — the workspace builds
//! offline, so the spec files, trace files, artifact manifest and run
//! configs are (de)serialized through this module instead of serde.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for our ASCII-ish files). Numbers are `f64`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn put(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), value.into()));
        } else {
            panic!("put on non-object");
        }
        self
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name (for required fields).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => bail!("expected number, got {}", other.kind()),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        // detlint: allow(float-eq) — exact integrality gate for the usize path: fract()==0 is representation-exact
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {}", other.kind()),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {}", other.kind()),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Ok(kv),
            other => bail!("expected object, got {}", other.kind()),
        }
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect()
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // ---- parsing --------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- serialization --------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            // Rust's shortest-roundtrip float formatting
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected '{}' at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u{hex}"))?,
                            );
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    // detlint: allow(unwrap) — the match arm guarantees rest starts with a non-empty UTF-8 scalar
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

/// Ordered-map helper for decoding objects with unknown key sets.
pub fn to_map(v: &Json) -> Result<BTreeMap<String, Json>> {
    Ok(v.as_obj()?
        .iter()
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert!(v.get("a").unwrap().as_arr().unwrap()[2]
            .get("b")
            .unwrap()
            .is_null());
    }

    #[test]
    fn float_roundtrip_exact() {
        let xs = [0.1, 1e-9, 123456.789, -2.5e17, f64::MIN_POSITIVE];
        for &x in &xs {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "{text}");
        }
    }

    #[test]
    fn integers_compact() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-3.0).to_string(), "-3");
    }

    #[test]
    fn string_escapes() {
        let s = "quote\" slash\\ nl\n tab\t unicode é";
        let text = Json::Str(s.to_string()).to_string();
        assert_eq!(Json::parse(&text).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap().as_str().unwrap(),
            "é"
        );
    }

    #[test]
    fn object_order_preserved() {
        let v = Json::obj().put("z", 1.0).put("a", 2.0);
        assert_eq!(v.to_string(), r#"{"z":1,"a":2}"#);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn accessors_type_errors() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_f64().is_err());
        assert!(v.as_obj().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn parses_own_spec_files() {
        let dir = crate::apps::spec::find_spec_dir(None).unwrap();
        for name in ["pose", "motion_sift"] {
            let text =
                std::fs::read_to_string(dir.join(format!("{name}.json"))).unwrap();
            let v = Json::parse(&text).unwrap();
            assert_eq!(v.get("name").unwrap().as_str().unwrap(), name);
            assert_eq!(v.get("params").unwrap().as_arr().unwrap().len(), 5);
        }
    }

    #[test]
    fn large_numeric_array_roundtrip() {
        let xs: Vec<f64> = (0..5000).map(|i| (i as f64) * 0.3171).collect();
        let text = Json::from_f64_slice(&xs).to_string();
        let back = Json::parse(&text).unwrap().as_f64_vec().unwrap();
        assert_eq!(back, xs);
    }
}
