//! Self-cleaning temporary directories for tests (offline stand-in for
//! the `tempfile` crate).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory removed on drop.
pub struct TestDir {
    path: PathBuf,
}

impl TestDir {
    pub fn new(tag: &str) -> TestDir {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "iptune-{tag}-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&path).expect("create test dir");
        TestDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn join(&self, rel: &str) -> PathBuf {
        self.path.join(rel)
    }
}

impl Drop for TestDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans() {
        let p;
        {
            let d = TestDir::new("unit");
            p = d.path().to_path_buf();
            std::fs::write(d.join("x.txt"), "hello").unwrap();
            assert!(p.is_dir());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TestDir::new("u");
        let b = TestDir::new("u");
        assert_ne!(a.path(), b.path());
    }
}
