//! In-tree substrates that keep the workspace building offline: a JSON
//! codec ([`json`]), a deterministic PRNG ([`rng`]), a micro-benchmark
//! harness ([`bench`]), a leveled stderr logger ([`log`]), a
//! property-testing loop ([`prop`]) and test tempdir helpers
//! ([`testdir`]).

pub mod bench;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod testdir;

pub use json::Json;
pub use rng::Rng;
