//! Deterministic, seedable PRNG — xoshiro256** with SplitMix64 seeding.
//!
//! This workspace builds offline with no `rand` crate, so the generator
//! the simulator, traces, controller and tests share lives here. Quality
//! is far beyond what the experiments need (xoshiro256** passes BigCrush)
//! and every stream is reproducible from a `u64` seed.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // multiply-shift; bias is negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (pairs cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-config / per-stage rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_500..11_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
