//! Minimal leveled stderr logger (ISSUE 7 satellite).
//!
//! Progress and status lines across the crate go through
//! [`log_info!`](crate::log_info) / [`log_verbose!`](crate::log_verbose)
//! / [`log_warn!`](crate::log_warn) instead of ad-hoc
//! `println!`/`eprintln!`, so stdout stays clean for machine-readable
//! output (JSON reports, result tables) and the CLI's `--quiet` /
//! `--verbose` flags work uniformly. Everything the logger emits goes to
//! stderr.
//!
//! Levels: `QUIET` silences info and verbose (warnings still print),
//! `INFO` (the default) shows progress lines, `VERBOSE` adds chatty
//! diagnostics.

use std::sync::atomic::{AtomicU8, Ordering};

pub const QUIET: u8 = 0;
pub const INFO: u8 = 1;
pub const VERBOSE: u8 = 2;

static LEVEL: AtomicU8 = AtomicU8::new(INFO);

/// Set the global log level (normally once, from CLI flag parsing).
pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// Would a message at `level` print?
pub fn enabled(level: u8) -> bool {
    level <= LEVEL.load(Ordering::Relaxed)
}

/// Progress/status line; suppressed by `--quiet`.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::INFO) {
            eprintln!($($arg)*);
        }
    };
}

/// Chatty diagnostics; shown only with `--verbose`.
#[macro_export]
macro_rules! log_verbose {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::VERBOSE) {
            eprintln!($($arg)*);
        }
    };
}

/// Warnings always print, even under `--quiet`.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        eprintln!($($arg)*);
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_gate_as_expected() {
        // Note: global state — keep this the only test that mutates it.
        set_level(QUIET);
        assert!(!enabled(INFO));
        assert!(!enabled(VERBOSE));
        set_level(VERBOSE);
        assert!(enabled(INFO));
        assert!(enabled(VERBOSE));
        set_level(INFO);
        assert!(enabled(INFO));
        assert!(!enabled(VERBOSE));
        assert_eq!(level(), INFO);
    }
}
