//! Micro-benchmark harness (offline stand-in for criterion): warmup,
//! repeated timed runs, median/mean/min reporting, and a tiny black-box.
//!
//! Used by every target under `rust/benches/` (all declared with
//! `harness = false`).

use std::time::{Duration, Instant};

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Bench runner: measures `f` (one logical iteration per call).
pub struct Bencher {
    /// Target wall-clock budget per benchmark.
    pub budget: Duration,
    pub warmup: Duration,
    pub results: Vec<BenchResult>,
    /// Scalar side-metrics (bytes, ratios, counts) recorded alongside the
    /// timings — `scripts/bench_gate.py` lifts them into the
    /// `BENCH_<sha>.json` trajectory so non-timing regressions (e.g. the
    /// ladder-trace peak memory) are visible across commits.
    pub metrics: Vec<(String, f64)>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_millis(900),
            warmup: Duration::from_millis(150),
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            budget: Duration::from_millis(250),
            warmup: Duration::from_millis(50),
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Bencher honoring the CI environment: `IPTUNE_BENCH_QUICK=1`
    /// switches to the quick profile (the `bench-smoke` job runs every
    /// target this way so wall-clock stays in seconds).
    pub fn from_env() -> Self {
        match std::env::var("IPTUNE_BENCH_QUICK") {
            Ok(v) if !matches!(v.to_ascii_lowercase().as_str(), "" | "0" | "false" | "no") => {
                Self::quick()
            }
            _ => Self::default(),
        }
    }

    /// Serialize the recorded results for the bench trajectory
    /// (`BENCH_<sha>.json` is assembled from these per-target files).
    pub fn to_json(&self, target: &str) -> crate::util::json::Json {
        use crate::util::json::Json;
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj()
                    .put("name", r.name.as_str())
                    .put("median_ns", r.median.as_nanos() as u64)
                    .put("mean_ns", r.mean.as_nanos() as u64)
                    .put("min_ns", r.min.as_nanos() as u64)
                    .put("iters", r.iters)
            })
            .collect();
        let mut metrics = Json::obj();
        for (name, value) in &self.metrics {
            metrics = metrics.put(name.as_str(), *value);
        }
        Json::obj()
            .put("target", target)
            .put("budget_ms", self.budget.as_millis() as u64)
            .put("results", Json::Arr(results))
            .put("metrics", metrics)
    }

    /// Write `$IPTUNE_BENCH_JSON_DIR/<target>.json` when that env var is
    /// set (no-op otherwise, so plain `cargo bench` stays file-free).
    /// Every bench target calls this last; the CI `bench-smoke` job
    /// merges the per-target files into the uploaded `BENCH_<sha>.json`.
    pub fn write_json_env(&self, target: &str) {
        let dir = match std::env::var("IPTUNE_BENCH_JSON_DIR") {
            Ok(d) if !d.is_empty() => d,
            _ => return,
        };
        let path = std::path::Path::new(&dir).join(format!("{target}.json"));
        if let Err(e) = std::fs::create_dir_all(&dir)
            .and_then(|_| std::fs::write(&path, self.to_json(target).to_string()))
        {
            crate::log_warn!("bench: could not write {}: {e}", path.display());
        } else {
            crate::log_info!("bench json -> {}", path.display());
        }
    }

    /// Time `f`, print a criterion-style line, and record the result.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup + calibration
        let mut n: u64 = 1;
        loop {
            let start = Instant::now();
            for _ in 0..n {
                f();
            }
            let el = start.elapsed();
            if el >= self.warmup {
                // aim for ~30 samples inside the budget
                let per = el.as_secs_f64() / n as f64;
                let per_sample = (self.budget.as_secs_f64() / 30.0 / per).max(1.0);
                n = per_sample as u64;
                break;
            }
            n = n.saturating_mul(2);
        }
        // sampling
        let mut samples: Vec<Duration> = Vec::new();
        let start_all = Instant::now();
        while start_all.elapsed() < self.budget || samples.len() < 5 {
            let start = Instant::now();
            for _ in 0..n {
                f();
            }
            samples.push(start.elapsed() / (n as u32));
            if samples.len() >= 100 {
                break;
            }
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let min = samples[0];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let result = BenchResult { name: name.to_string(), iters: n, mean, median, min };
        crate::log_info!(
            "bench {:<44} median {:>12} mean {:>12} min {:>12} ({} samples x {} iters)",
            result.name,
            fmt_dur(median),
            fmt_dur(mean),
            fmt_dur(min),
            samples.len(),
            n
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Look up a previous result by name.
    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Record a scalar side-metric (printed and serialized with the run).
    pub fn metric(&mut self, name: &str, value: f64) {
        crate::log_info!("metric {name:<42} {value}");
        self.metrics.push((name.to_string(), value));
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            budget: Duration::from_millis(30),
            warmup: Duration::from_millis(5),
            results: Vec::new(),
            metrics: Vec::new(),
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let r = b.result("noop-ish").unwrap();
        assert!(r.median.as_nanos() < 1_000_000);
        assert!(r.iters >= 1);
    }

    #[test]
    fn bench_json_shape() {
        let mut b = Bencher {
            budget: Duration::from_millis(20),
            warmup: Duration::from_millis(2),
            results: Vec::new(),
            metrics: Vec::new(),
        };
        let mut acc = 0u64;
        b.bench("x/one", || {
            acc = black_box(acc.wrapping_add(3));
        });
        b.metric("x/bytes", 1234.0);
        let j = crate::util::json::Json::parse(&b.to_json("x").to_string()).unwrap();
        assert_eq!(j.req("target").unwrap().as_str().unwrap(), "x");
        let rs = j.req("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].req("name").unwrap().as_str().unwrap(), "x/one");
        assert!(rs[0].req("median_ns").unwrap().as_u64().unwrap() > 0);
        let m = j.req("metrics").unwrap();
        assert_eq!(m.req("x/bytes").unwrap().as_f64().unwrap(), 1234.0);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
