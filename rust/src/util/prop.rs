//! Lightweight property-based testing loop (offline stand-in for
//! proptest): run a property over many seeded random cases and report the
//! first failing seed so the case can be replayed.

use super::rng::Rng;

/// Number of cases per property by default.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop(rng, case_index)` for `cases` seeded cases; panic with the
/// failing seed on the first failure. `prop` should panic/assert inside.
pub fn check<F: FnMut(&mut Rng, usize)>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000_0000_0000u64 ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case)
        }));
        if let Err(e) = result {
            crate::log_warn!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random vector of `len` uniform values in [0,1).
pub fn unit_vec(rng: &mut Rng, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.f64()).collect()
}

/// Random weighted DAG in topological order: returns (deps per node,
/// weights). Node 0 is always a source.
pub fn random_dag(rng: &mut Rng, max_nodes: usize) -> (Vec<Vec<usize>>, Vec<f64>) {
    let n = 2 + rng.below(max_nodes.saturating_sub(2).max(1));
    let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
    for i in 0..n {
        let mut d = Vec::new();
        if i > 0 {
            // each node depends on a random non-empty subset of earlier nodes
            let k = 1 + rng.below(i.min(3));
            for _ in 0..k {
                let cand = rng.below(i);
                if !d.contains(&cand) {
                    d.push(cand);
                }
            }
        }
        deps.push(d);
    }
    let weights = (0..n).map(|_| rng.range_f64(0.1, 50.0)).collect();
    (deps, weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counts", 10, |_rng, _case| {
            count += 1;
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fails", 5, |_rng, case| {
            assert!(case < 3, "boom");
        });
    }

    #[test]
    fn random_dag_is_topological() {
        check("dag", 20, |rng, _| {
            let (deps, w) = random_dag(rng, 12);
            assert_eq!(deps.len(), w.len());
            for (i, d) in deps.iter().enumerate() {
                for &dep in d {
                    assert!(dep < i);
                }
            }
        });
    }
}
