//! Pose detection under a visual-servoing deadline (paper Sec. 2.1):
//! "as this application is intended for visual servoing of a robot arm,
//! it requires very tight end-to-end latencies; our goal is a 50 ms
//! latency bound."
//!
//! Runs the ε-greedy tuner on the pose app at L = 50 ms and prints the
//! operating points it settles on — which knob settings buy a 7×
//! speedup over the fidelity-maximizing defaults, and at what fidelity
//! cost.
//!
//! ```bash
//! cargo run --release --example pose_servoing
//! ```

use std::collections::HashMap;

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::runtime::native::NativeBackend;
use iptune::trace::TraceSet;
use iptune::tuner::{EpsGreedyController, TunerConfig};

fn main() -> anyhow::Result<()> {
    let spec_dir = find_spec_dir(None)?;
    let app = app_by_name("pose", &spec_dir)?;
    let bound = 50.0;
    let frames = 1000;

    println!("== pose detection @ L = {bound} ms (visual servoing) ==");
    let defaults = app.spec.defaults();
    let content = app.model.content(0);
    let default_lat: f64 = app.stage_latencies(&defaults, &content).iter().sum();
    println!(
        "defaults: latency {:.0} ms, fidelity {:.3}  (the paper's fidelity-max corner)",
        default_lat,
        app.model.fidelity(&defaults, &content)
    );

    let traces = TraceSet::generate_default(&app, 7);
    let backend = NativeBackend::structured(&app.spec);
    let eps = TunerConfig::epsilon_for_horizon(frames);
    let cfg = TunerConfig { epsilon: eps, bound_ms: bound, warmup_frames: 25 };
    let mut ctl = EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 3);
    let out = ctl.run(frames);

    // which actions did exploitation settle on?
    let mut counts: HashMap<usize, usize> = HashMap::new();
    for s in out.steps.iter().filter(|s| !s.explored && s.frame > 200) {
        *counts.entry(s.action).or_insert(0) += 1;
    }
    let mut top: Vec<(usize, usize)> = counts.into_iter().collect();
    top.sort_by(|a, b| b.1.cmp(&a.1));

    println!("\ntop operating points chosen after convergence:");
    println!(
        "{:>6} {:>7} {:>9} {:>9}  K1(scale) K2(thresh) K3(parSIFT) K4(parMatch) K5(parClust)",
        "action", "frames", "cost(ms)", "fidelity"
    );
    for &(a, n) in top.iter().take(4) {
        let t = &traces.traces[a];
        println!(
            "{:>6} {:>7} {:>9.1} {:>9.3}  {:>9.2} {:>10.0} {:>11.0} {:>12.0} {:>12.0}",
            a,
            n,
            t.avg_cost_ms(),
            t.avg_fidelity(),
            t.config[0],
            t.config[1],
            t.config[2],
            t.config[3],
            t.config[4]
        );
    }

    println!("\n== outcome over {frames} frames ==");
    println!("avg fidelity   : {:.3}", out.avg_reward);
    println!(
        "avg violation  : {:.1} ms | max {:.1} ms | over-bound {:.1}% of frames",
        out.avg_violation_ms,
        out.max_violation_ms,
        100.0 * out.violation_rate
    );
    println!(
        "speedup vs defaults: {:.1}x (from {:.0} ms to the {bound} ms envelope)",
        default_lat / bound,
        default_lat
    );
    Ok(())
}
