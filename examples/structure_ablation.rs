//! Structure ablation (paper Sec. 4.3 / Fig. 7): structured vs
//! unstructured cubic predictors on the MotionSIFT app — expected error,
//! max-norm error, compact feature counts (30 vs 56) and measured update
//! throughput. Also sweeps the kernel degree (Fig. 6's linear/quadratic/
//! cubic comparison) for both apps.
//!
//! ```bash
//! cargo run --release --example structure_ablation
//! ```

use std::time::Instant;

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::learner::{StagePredictor, Variant};
use iptune::metrics::ErrorTracker;
use iptune::trace::TraceSet;
use iptune::util::Rng;

fn main() -> anyhow::Result<()> {
    let spec_dir = find_spec_dir(None)?;
    for name in ["pose", "motion_sift"] {
        let app = app_by_name(name, &spec_dir)?;
        let traces = TraceSet::generate(&app, 30, 1000, 7);
        let candidates: Vec<Vec<f64>> =
            traces.configs().iter().map(|c| app.spec.normalize(c)).collect();

        println!("== {} ==", app.spec.title);
        println!(
            "{:<14} {:>6} {:>10} {:>12} {:>12} {:>12}",
            "predictor", "deg", "features", "expected", "max-norm", "updates/s"
        );
        for (variant, degrees) in [
            (Variant::Unstructured, vec![1usize, 2, 3]),
            (Variant::Structured, vec![3usize]),
        ] {
            for &deg in &degrees {
                let mut pred = StagePredictor::new(&app.spec, variant, deg);
                let mut tracker = ErrorTracker::new();
                let mut rng = Rng::new(9);
                let start = Instant::now();
                let frames = 1000;
                for t in 0..frames {
                    let a = rng.below(candidates.len());
                    let rec = traces.frame(a, t % traces.num_frames());
                    let before =
                        pred.observe(&candidates[a], &rec.stage_ms, rec.end_to_end_ms);
                    tracker.observe((before - rec.end_to_end_ms).abs());
                }
                let elapsed = start.elapsed().as_secs_f64();
                println!(
                    "{:<14} {:>6} {:>10} {:>12.2} {:>12.1} {:>12.0}",
                    variant.as_str(),
                    deg,
                    pred.num_features(),
                    tracker.expected(),
                    tracker.max_norm(),
                    frames as f64 / elapsed
                );
            }
        }
        println!();
    }
    println!("paper expectations: cubic < quadratic < linear expected error;");
    println!("structured ~= unstructured expected error with fewer features");
    println!("(30 vs 56 on MotionSIFT) and cheaper updates, smaller max-norm error.");
    Ok(())
}
