//! Quickstart — the end-to-end driver (DESIGN.md §Experiment index).
//!
//! Exercises every layer on a real small workload: generate the paper's
//! 30×1000 execution traces on the simulated 15-node cluster, load the
//! AOT-compiled HLO predictor artifacts through the PJRT runtime (L1/L2,
//! built once by `make artifacts`), and run the ε-greedy constrained
//! controller (L3) for 1000 frames at the paper's ε = 1/√T, reporting
//! fidelity vs the clairvoyant optimum and the constraint violations.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Falls back to the native backend (identical math, compact features)
//! when artifacts are absent.

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::learner::Variant;
use iptune::runtime::native::NativeBackend;
use iptune::runtime::xla::XlaBackend;
use iptune::runtime::Backend;
use iptune::trace::TraceSet;
use iptune::tuner::policy::oracle_best;
use iptune::tuner::{EpsGreedyController, TunerConfig};

fn main() -> anyhow::Result<()> {
    let spec_dir = find_spec_dir(None)?;
    let app = app_by_name("motion_sift", &spec_dir)?;
    let bound = app.spec.latency_bounds_ms[0];
    let frames = 1000;

    println!("== iptune quickstart: {} ==", app.spec.title);
    println!(
        "generating {} configs x {} frames on the simulated {}-core cluster ...",
        app.spec.trace_configs,
        app.spec.trace_frames,
        iptune::simulator::Cluster::default().total_cores()
    );
    let traces = TraceSet::generate_default(&app, 7);
    let payoffs = traces.payoffs();
    let (lo, hi) = payoffs
        .iter()
        .fold((f64::INFINITY, 0.0f64), |(l, h), &(c, _)| (l.min(c), h.max(c)));
    println!("action space: avg cost {lo:.0}..{hi:.0} ms, bound L = {bound} ms");

    let backend: Box<dyn Backend> =
        match XlaBackend::from_default_artifacts(&app.spec, Variant::Structured) {
            Ok(b) => {
                println!("backend: XLA (PJRT, AOT-compiled HLO artifacts)");
                Box::new(b)
            }
            Err(e) => {
                println!("backend: native (XLA artifacts unavailable: {e})");
                Box::new(NativeBackend::structured(&app.spec))
            }
        };

    let eps = TunerConfig::epsilon_for_horizon(frames);
    println!("controller: eps-greedy, eps = 1/sqrt(T) = {eps:.3}, {frames} frames\n");
    let cfg = TunerConfig { epsilon: eps, bound_ms: bound, warmup_frames: 25 };
    let mut ctl = EpsGreedyController::new(&app.spec, &traces, backend, cfg, 11);

    let mut window_reward = 0.0;
    let mut window_viol = 0.0;
    let mut outcome = Vec::with_capacity(frames);
    for f in 0..frames {
        let s = ctl.step(f);
        window_reward += s.reward;
        window_viol += s.violation_ms;
        if f % 100 == 99 {
            println!(
                "frames {:>4}-{:>4}: avg fidelity {:.3}, avg violation {:>6.1} ms",
                f - 99,
                f,
                window_reward / 100.0,
                window_viol / 100.0
            );
            window_reward = 0.0;
            window_viol = 0.0;
        }
        outcome.push(s);
    }

    let avg_reward = outcome.iter().map(|s| s.reward).sum::<f64>() / frames as f64;
    let avg_viol = outcome.iter().map(|s| s.violation_ms).sum::<f64>() / frames as f64;
    let max_viol = outcome.iter().map(|s| s.violation_ms).fold(0.0, f64::max);
    let explored = outcome.iter().filter(|s| s.explored).count();
    let oracle = oracle_best(&traces, frames, bound);

    println!("\n== results ==");
    println!(
        "avg fidelity      : {:.3}  ({:.1}% of clairvoyant optimum {:.3})",
        avg_reward,
        100.0 * avg_reward / oracle.avg_reward,
        oracle.avg_reward
    );
    println!(
        "constraint (L={bound} ms): avg violation {:.1} ms ({:.3} s), max {:.1} ms",
        avg_viol,
        avg_viol / 1000.0,
        max_viol
    );
    println!("explored          : {explored} / {frames} frames ({:.1}%)",
             100.0 * explored as f64 / frames as f64);
    println!("\npaper targets: >= 90% of optimum at ~3% exploration; avg violation ~0.03 s");
    Ok(())
}
