//! Non-stationarity demo (paper Sec. 4.2 / Fig. 6): "the increase in the
//! pose detection dataset at frame 600 corresponds to a change in the
//! scene, in which a notebook appeared. This increased the number of SIFT
//! features ... and consequently the computational requirements."
//!
//! Tracks the online predictor's per-frame error through the scene
//! change: the error spikes when the notebook enters, then falls again as
//! OGD adapts — the core argument for learning *online* rather than
//! calibrating offline once.
//!
//! ```bash
//! cargo run --release --example scene_change
//! ```

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::learner::{StagePredictor, Variant};
use iptune::trace::TraceSet;
use iptune::util::Rng;

fn main() -> anyhow::Result<()> {
    let spec_dir = find_spec_dir(None)?;
    let app = app_by_name("pose", &spec_dir)?;
    let frames = 1000;

    println!("== pose detection: scene change at frame 600 ==");
    let traces = TraceSet::generate(&app, 20, frames, 7);
    let candidates: Vec<Vec<f64>> =
        traces.configs().iter().map(|c| app.spec.normalize(c)).collect();

    let mut pred = StagePredictor::new(&app.spec, Variant::Structured, 3);
    let mut rng = Rng::new(5);
    let mut errs = Vec::with_capacity(frames);
    let mut lats = Vec::with_capacity(frames);
    for t in 0..frames {
        let a = rng.below(candidates.len());
        let rec = traces.frame(a, t);
        let before = pred.observe(&candidates[a], &rec.stage_ms, rec.end_to_end_ms);
        errs.push((before - rec.end_to_end_ms).abs());
        lats.push(rec.end_to_end_ms);
    }

    println!("\nper-window mean |prediction error| (ms) and observed latency (ms):");
    println!("{:>12} {:>12} {:>12}", "frames", "err", "latency");
    for w in (0..frames).step_by(50) {
        let hi = (w + 50).min(frames);
        let err = errs[w..hi].iter().sum::<f64>() / (hi - w) as f64;
        let lat = lats[w..hi].iter().sum::<f64>() / (hi - w) as f64;
        let marker = if (550..650).contains(&w) { "  <- scene change" } else { "" };
        println!("{:>6}-{:<5} {:>12.1} {:>12.1}{marker}", w, hi - 1, err, lat);
    }

    let before = errs[500..590].iter().sum::<f64>() / 90.0;
    let spike = errs[600..660].iter().sum::<f64>() / 60.0;
    let after = errs[800..1000].iter().sum::<f64>() / 200.0;
    println!("\nsummary: before {before:.1} ms | at change {spike:.1} ms | re-adapted {after:.1} ms");
    println!(
        "the online learner {} the notebook's extra SIFT features.",
        if after < spike { "absorbed" } else { "did NOT absorb" }
    );
    Ok(())
}
