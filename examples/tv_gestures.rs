//! Gesture-controlled TV on the *live* streaming engine (paper Fig. 3/4):
//! a responsive interface needs ~100 ms end-to-end. This example runs the
//! full closed loop on the threaded data-flow engine — stages as
//! concurrent tasks with bounded connectors, per-stage latency probes,
//! online learning, and dynamic retuning of the running pipeline —
//! exactly the deployment story of paper Sec. 2.
//!
//! ```bash
//! cargo run --release --example tv_gestures
//! ```

use std::sync::Arc;

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::engine::{spawn_stream, EngineConfig};
use iptune::runtime::native::NativeBackend;
use iptune::runtime::Backend;
use iptune::util::Rng;

fn main() -> anyhow::Result<()> {
    let spec_dir = find_spec_dir(None)?;
    let app = Arc::new(app_by_name("motion_sift", &spec_dir)?);
    let bound = 100.0;
    let frames = 600;
    let retune_every = 20;

    println!("== TV gesture control on the streaming engine (L = {bound} ms) ==");
    println!("pipeline: {}", app.graph.to_dot("tv").lines().count() - 2);
    let handle = spawn_stream(
        Arc::clone(&app),
        app.spec.defaults(), // start at the fidelity-max corner (slow!)
        EngineConfig { frames, realtime_scale: 1e-5, queue_capacity: 8, seed: 3 },
    );

    let mut backend = NativeBackend::structured(&app.spec);
    let mut rng = Rng::new(17);
    // candidate grid: random valid configs + the defaults
    let mut candidates: Vec<Vec<f64>> = (0..48)
        .map(|_| (0..app.spec.num_vars()).map(|_| rng.f64()).collect())
        .collect();
    candidates.push(app.spec.normalize(&app.spec.defaults()));
    let content = app.model.content(0);
    let rewards: Vec<f64> = candidates
        .iter()
        .map(|u| app.model.fidelity(&app.spec.denormalize(u), &content))
        .collect();

    let (mut lat, mut fid, mut over, mut n) = (0.0, 0.0, 0usize, 0usize);
    let mut tail_stats = (0.0f64, 0usize, 0usize); // (lat sum, over, n)
    while let Ok(rec) = handle.records.recv() {
        let u = app.spec.normalize(&rec.knobs);
        let (y, off) = backend.group_map().targets(&rec.stage_ms, rec.end_to_end_ms);
        backend.update(&u, &y);
        backend.observe_offset(off);
        lat += rec.end_to_end_ms;
        fid += rec.fidelity;
        n += 1;
        if rec.end_to_end_ms > bound {
            over += 1;
        }
        if rec.frame >= frames - 200 {
            tail_stats.0 += rec.end_to_end_ms;
            tail_stats.2 += 1;
            if rec.end_to_end_ms > bound {
                tail_stats.1 += 1;
            }
        }
        if rec.frame % retune_every == retune_every - 1 {
            let pick = backend.solve(&candidates, &rewards, bound);
            let ks = app.spec.denormalize(&candidates[pick]);
            if rec.frame % 100 == 99 {
                println!(
                    "frame {:>4}: window avg latency {:>7.1} ms, fidelity {:.3}, over-bound {:>3}/{:>3} -> K = [{:.1}, {:.1}, {:.0}, {:.0}, {:.0}]",
                    rec.frame, lat / n as f64, fid / n as f64, over, n,
                    ks[0], ks[1], ks[2], ks[3], ks[4]
                );
                (lat, fid, over, n) = (0.0, 0.0, 0, 0);
            }
            handle.set_knobs(ks);
        }
    }

    println!("\n== steady state (last 200 frames) ==");
    println!(
        "avg latency {:.1} ms | over-bound {:.1}% | target {} ms",
        tail_stats.0 / tail_stats.2 as f64,
        100.0 * tail_stats.1 as f64 / tail_stats.2 as f64,
        bound
    );
    Ok(())
}
